"""RWKV-6 "Finch" (rwkv6-1.6b): attention-free LM with data-dependent decay.

Training uses a *chunked* WKV scan: within a chunk the recurrence is expanded
into a bounded pairwise form (all exponents are differences of cumulative
log-decays, hence <= 0 and overflow-safe), and chunk-to-chunk state is carried
with ``lax.scan``. Decode carries the (B, H, K, V) wkv state plus the
token-shift hiddens, so serving cost is sequence-length independent — this is
why rwkv6 runs the ``long_500k`` cell that full-attention archs skip.

Math (per head, state S in R^{KxV}, decay w_t in (0,1)^K, bonus u in R^K):
  o_t = r_t @ (S_{t-1} + (u * k_t) v_t^T)
  S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import losses
from repro.models import module as nn
from repro.models import transformer as tfm
from repro.models.model_api import Model, _input_specs, register_family
from repro.sharding.plan import ShardingPlan

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# chunked WKV core (shared by ref oracle and model; Pallas kernel mirrors it)
# ---------------------------------------------------------------------------


def wkv_chunked(
    r: jax.Array,  # (B, T, H, K)
    k: jax.Array,  # (B, T, H, K)
    v: jax.Array,  # (B, T, H, V)
    logw: jax.Array,  # (B, T, H, K), log-decay, <= 0
    u: jax.Array,  # (H, K) bonus
    state0: jax.Array,  # (B, H, K, V)
    chunk: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,T,H,V) f32, final state (B,H,K,V) f32)."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0, f"T={T} must be divisible by chunk={chunk}"
    n = T // chunk

    rc = r.astype(jnp.float32).reshape(B, n, chunk, H, K).transpose(1, 0, 3, 2, 4)
    kc = k.astype(jnp.float32).reshape(B, n, chunk, H, K).transpose(1, 0, 3, 2, 4)
    vc = v.astype(jnp.float32).reshape(B, n, chunk, H, V).transpose(1, 0, 3, 2, 4)
    wc = logw.astype(jnp.float32).reshape(B, n, chunk, H, K).transpose(1, 0, 3, 2, 4)
    # shapes now (n, B, H, C, K/V)

    uf = u.astype(jnp.float32)
    tri_strict = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_), k=-1)

    def body(S, inputs):
        rb, kb, vb, wb = inputs  # (B,H,C,K/V)
        clw = jnp.cumsum(wb, axis=2)  # inclusive cumulative log-decay
        clw_ex = clw - wb  # exclusive
        # pairwise decay exponent for s<t: sum_{s<tau<t... } = clw_ex[t]-clw[s] <= 0
        diff = clw_ex[:, :, :, None, :] - clw[:, :, None, :, :]  # (B,H,C,C,K)
        decay = jnp.exp(jnp.where(tri_strict[None, None, :, :, None], diff, -jnp.inf))
        scores = jnp.einsum("bhtk,bhsk,bhtsk->bhts", rb, kb, decay)
        # diagonal bonus term: r_t . (u * k_t)
        diag = jnp.einsum("bhtk,hk->bht", rb * kb, uf)
        out = jnp.einsum("bhts,bhsv->bhtv", scores, vb)
        out = out + diag[..., None] * vb
        # cross-chunk: r_t decayed to chunk start @ S
        rdec = rb * jnp.exp(clw_ex)
        out = out + jnp.einsum("bhtk,bhkv->bhtv", rdec, S)
        # state update: S' = exp(clw[-1]) * S + sum_s exp(clw[-1]-clw[s]) k_s v_s^T
        last = clw[:, :, -1:, :]  # (B,H,1,K)
        kdec = kb * jnp.exp(last - clw)
        S_new = jnp.exp(last[:, :, 0, :])[..., None] * S + jnp.einsum(
            "bhsk,bhsv->bhkv", kdec, vb
        )
        return S_new, out

    state, outs = jax.lax.scan(body, state0.astype(jnp.float32), (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, T, H, V)
    return out, state


def wkv_step(r, k, v, logw, u, state):
    """Single-token recurrence. r/k/logw: (B,H,K); v: (B,H,V); state (B,H,K,V)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    kv = kf[..., :, None] * vf[..., None, :]  # (B,H,K,V)
    out = jnp.einsum("bhk,bhkv->bhv", rf, state + u[None, :, :, None] * kv)
    state = w[..., None] * state + kv
    return out, state


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


def _lora_init(kg, d: int, rank: int, out: int) -> Params:
    return {
        "a": nn.fan_in_init(kg(), (d, rank), jnp.bfloat16),
        "b": nn.zeros_init(kg(), (rank, out), jnp.bfloat16),
    }


def _lora(p: Params, x: jax.Array) -> jax.Array:
    h = jnp.tanh(jnp.einsum("...d,dr->...r", x, p["a"].astype(x.dtype)))
    return jnp.einsum("...r,ro->...o", h, p["b"].astype(x.dtype))


def init_time_mix(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    d = cfg.d_model
    s = cfg.ssm
    H = d // s.head_dim
    p: Params = {
        "mu": 0.5 * jnp.ones((5, d), jnp.bfloat16),  # r,k,v,w,g lerp weights
        "w_r": nn.fan_in_init(kg(), (d, d), jnp.bfloat16),
        "w_k": nn.fan_in_init(kg(), (d, d), jnp.bfloat16),
        "w_v": nn.fan_in_init(kg(), (d, d), jnp.bfloat16),
        "w_g": nn.fan_in_init(kg(), (d, d), jnp.bfloat16),
        "w_out": nn.fan_in_init(
            kg(), (d, d), jnp.bfloat16, scale=1.0 / (2 * cfg.n_layers) ** 0.5
        ),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),  # w0: strong decay init
        "decay_lora": _lora_init(kg, d, s.lora_rank, d),
        "bonus_u": 0.5 * jnp.ones((H, s.head_dim), jnp.float32),
        "ln_out": nn.layernorm_init(d),
    }
    return p


def init_channel_mix(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu": 0.5 * jnp.ones((2, d), jnp.bfloat16),  # k, r lerps
        "w_in": nn.fan_in_init(kg(), (d, f), jnp.bfloat16),
        "w_r": nn.fan_in_init(kg(), (d, d), jnp.bfloat16),
        "w_out": nn.fan_in_init(
            kg(), (f, d), jnp.bfloat16, scale=1.0 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def init_block(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    return {
        "tm_norm": nn.layernorm_init(cfg.d_model),
        "time_mix": init_time_mix(cfg, kg()),
        "cm_norm": nn.layernorm_init(cfg.d_model),
        "channel_mix": init_channel_mix(cfg, kg()),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    return {
        "embed": nn.embedding_init(kg(), cfg.padded_vocab, cfg.d_model),
        "embed_norm": nn.layernorm_init(cfg.d_model),
        "layers": nn.stack_layer_init(
            functools.partial(init_block, cfg), kg(), cfg.n_layers
        ),
        "final_norm": nn.layernorm_init(cfg.d_model),
        "lm_head": {"w_lm": nn.fan_in_init(kg(), (cfg.d_model, cfg.padded_vocab), jnp.bfloat16)},
    }


def _token_shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """x_{t-1}; for t=0 uses ``prev`` (decode carry) or zeros."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)


def time_mix_seq(
    cfg: ModelConfig, p: Params, x: jax.Array, plan: ShardingPlan,
    state0: jax.Array, x_prev: jax.Array | None = None,
):
    """Sequence-mode time mixing. x: (B,T,d). Returns (y, new_state, last_x)."""
    B, T, d = x.shape
    s = cfg.ssm
    H, K = d // s.head_dim, s.head_dim
    xp = _token_shift(x, x_prev)
    mu = p["mu"]
    xr, xk, xv, xw, xg = (_lerp(x, xp, mu[i]) for i in range(5))
    r = nn.dense_apply({"w": p["w_r"]}, xr).reshape(B, T, H, K)
    k = nn.dense_apply({"w": p["w_k"]}, xk).reshape(B, T, H, K)
    v = nn.dense_apply({"w": p["w_v"]}, xv).reshape(B, T, H, K)
    g = nn.dense_apply({"w": p["w_g"]}, xg)
    # data-dependent decay (Finch): logw = -exp(w0 + lora(xw)), in (-inf, 0)
    ww = p["decay_base"].astype(jnp.float32) + _lora(p["decay_lora"], xw).astype(
        jnp.float32
    )
    logw = -jnp.exp(ww).reshape(B, T, H, K)
    r, k = plan.act(r, "heads"), plan.act(k, "heads")
    if jax.default_backend() == "tpu" and T % s.chunk == 0:
        from repro.kernels import ops as kops  # Pallas hot path

        out, state = kops.wkv6(r, k, v, logw, p["bonus_u"], state0,
                               chunk=s.chunk, mode="tpu")
    else:
        out, state = wkv_chunked(r, k, v, logw, p["bonus_u"], state0,
                                 chunk=s.chunk)
    out = plan.act(out.astype(jnp.bfloat16), "heads")
    out = nn.layernorm_apply(p["ln_out"], out.reshape(B, T, d))  # group-norm-ish
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(out.dtype)
    y = nn.dense_apply({"w": p["w_out"]}, out)
    return y, state, x[:, -1, :]


def channel_mix_seq(
    cfg: ModelConfig, p: Params, x: jax.Array, x_prev: jax.Array | None = None
):
    xp = _token_shift(x, x_prev)
    xk = _lerp(x, xp, p["mu"][0])
    xr = _lerp(x, xp, p["mu"][1])
    h = nn.dense_apply({"w": p["w_in"]}, xk)
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(h.dtype)
    r = jax.nn.sigmoid(
        nn.dense_apply({"w": p["w_r"]}, xr).astype(jnp.float32)
    ).astype(h.dtype)
    return r * nn.dense_apply({"w": p["w_out"]}, h), x[:, -1, :]


def block_seq(cfg: ModelConfig, plan: ShardingPlan, x, lp: Params, state0):
    y, state, tm_last = time_mix_seq(
        cfg, lp["time_mix"], nn.layernorm_apply(lp["tm_norm"], x), plan, state0
    )
    x = plan.act(x + y, "hidden")
    y, cm_last = channel_mix_seq(
        cfg, lp["channel_mix"], nn.layernorm_apply(lp["cm_norm"], x)
    )
    x = plan.act(x + y, "hidden")
    return x, state, (tm_last, cm_last)


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, plan: ShardingPlan):
    B, T = tokens.shape
    s = cfg.ssm
    H, K = cfg.d_model // s.head_dim, s.head_dim
    h = nn.embedding_apply(params["embed"], tokens)
    h = nn.layernorm_apply(params["embed_norm"], h)
    h = plan.act(h, "hidden")
    state0 = jnp.zeros((B, H, K, K), jnp.float32)

    def body(x, lp):
        x, _, _ = block_seq(cfg, plan, x, lp, state0)
        return x

    h = nn.scan_layers(body, h, params["layers"], remat=cfg.remat)
    h = nn.layernorm_apply(params["final_norm"], h)
    logits = tfm.mask_pad_logits(cfg, nn.dense_apply({"w": params["lm_head"]["w_lm"]}, h))
    return plan.act(logits, "logits")


# ---------------------------------------------------------------------------
# serving: state cache = {wkv (L,B,H,K,V), tm_x (L,B,d), cm_x (L,B,d)}
# ---------------------------------------------------------------------------


def cache_spec(cfg: ModelConfig, batch: int, _max_len: int):
    s = cfg.ssm
    H, K = cfg.d_model // s.head_dim, s.head_dim
    L, d = cfg.n_layers, cfg.d_model
    return {
        "wkv": jax.ShapeDtypeStruct((L, batch, H, K, K), jnp.float32),
        "tm_x": jax.ShapeDtypeStruct((L, batch, d), jnp.bfloat16),
        "cm_x": jax.ShapeDtypeStruct((L, batch, d), jnp.bfloat16),
    }


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, plan: ShardingPlan):
    B, T = tokens.shape
    s = cfg.ssm
    H, K = cfg.d_model // s.head_dim, s.head_dim
    h = nn.layernorm_apply(
        params["embed_norm"], nn.embedding_apply(params["embed"], tokens)
    )
    h = plan.act(h, "hidden")
    state0 = jnp.zeros((B, H, K, K), jnp.float32)

    def body(x, lp):
        x, state, (tm_last, cm_last) = block_seq(cfg, plan, x, lp, state0)
        return x, (state, tm_last.astype(jnp.bfloat16), cm_last.astype(jnp.bfloat16))

    h, (states, tm_xs, cm_xs) = jax.lax.scan(body, h, params["layers"])
    h = nn.layernorm_apply(params["final_norm"], h[:, -1:, :])
    logits = tfm.mask_pad_logits(cfg, nn.dense_apply({"w": params["lm_head"]["w_lm"]}, h))[:, 0, :]
    cache = {
        "wkv": plan.act(states, "state"),
        "tm_x": tm_xs,
        "cm_x": cm_xs,
    }
    return plan.act(logits, "last_logits"), cache


def decode_step(cfg, params, token, cache, _pos, plan: ShardingPlan):
    B = token.shape[0]
    s = cfg.ssm
    d = cfg.d_model
    H, K = d // s.head_dim, s.head_dim
    x = nn.layernorm_apply(
        params["embed_norm"], nn.embedding_apply(params["embed"], token[:, None])
    )[:, 0, :]  # (B, d)

    def body(x, layer_in):
        lp, wkv, tm_x, cm_x = layer_in
        tm = lp["time_mix"]
        xn_tm = nn.layernorm_apply(lp["tm_norm"], x)
        xn = xn_tm
        mu = tm["mu"]
        xr, xk, xv, xw, xg = (_lerp(xn, tm_x.astype(xn.dtype), mu[i]) for i in range(5))
        r = nn.dense_apply({"w": tm["w_r"]}, xr).reshape(B, H, K)
        k = nn.dense_apply({"w": tm["w_k"]}, xk).reshape(B, H, K)
        v = nn.dense_apply({"w": tm["w_v"]}, xv).reshape(B, H, K)
        g = nn.dense_apply({"w": tm["w_g"]}, xg)
        ww = tm["decay_base"].astype(jnp.float32) + _lora(tm["decay_lora"], xw).astype(
            jnp.float32
        )
        logw = -jnp.exp(ww).reshape(B, H, K)
        out, wkv_new = wkv_step(r, k, v, logw, tm["bonus_u"], wkv)
        out = nn.layernorm_apply(tm["ln_out"], out.astype(jnp.bfloat16).reshape(B, d))
        out = out * jax.nn.silu(g.astype(jnp.float32)).astype(out.dtype)
        x = x + nn.dense_apply({"w": tm["w_out"]}, out)
        # channel mix
        cm = lp["channel_mix"]
        xn_cm = nn.layernorm_apply(lp["cm_norm"], x)
        xk2 = _lerp(xn_cm, cm_x.astype(xn_cm.dtype), cm["mu"][0])
        xr2 = _lerp(xn_cm, cm_x.astype(xn_cm.dtype), cm["mu"][1])
        hh = nn.dense_apply({"w": cm["w_in"]}, xk2)
        hh = jnp.square(jax.nn.relu(hh.astype(jnp.float32))).astype(hh.dtype)
        rr = jax.nn.sigmoid(
            nn.dense_apply({"w": cm["w_r"]}, xr2).astype(jnp.float32)
        ).astype(hh.dtype)
        x = x + rr * nn.dense_apply({"w": cm["w_out"]}, hh)
        # carries: the *inputs* each mixer saw this step (token-shift sources)
        return x, (wkv_new, xn_tm.astype(jnp.bfloat16), xn_cm.astype(jnp.bfloat16))

    x, (wkv_new, tm_new, cm_new) = jax.lax.scan(
        body, x, (params["layers"], cache["wkv"], cache["tm_x"], cache["cm_x"])
    )
    x = nn.layernorm_apply(params["final_norm"], x)
    logits = tfm.mask_pad_logits(cfg, nn.dense_apply({"w": params["lm_head"]["w_lm"]}, x))
    return plan.act(logits, "last_logits"), {
        "wkv": plan.act(wkv_new, "state"),
        "tm_x": tm_new,
        "cm_x": cm_new,
    }


@register_family("rwkv")
def _build_rwkv(cfg: ModelConfig) -> Model:
    def loss(params, batch, plan: ShardingPlan):
        logits = forward(cfg, params, batch["tokens"], plan)
        return losses.softmax_cross_entropy(logits, batch["labels"])

    return Model(
        cfg=cfg,
        init=lambda key: init_params(cfg, key),
        loss=loss,
        prefill=lambda params, batch, plan: prefill(cfg, params, batch["tokens"], plan),
        decode=lambda params, batch, cache, pos, plan: decode_step(
            cfg, params, batch["token"], cache, pos, plan
        ),
        cache_spec=lambda b, s: cache_spec(cfg, b, s),
        input_specs=lambda suite: _input_specs(cfg, suite),
    )
