"""Unified model API: one ``Model`` facade per architecture family.

Every family exposes the same surface so the runtime, collocation scheduler,
dry-run, and benchmarks never branch on architecture:

  init(key)                       -> params pytree
  loss(params, batch, plan)       -> (scalar, metrics)
  prefill(params, batch, plan)    -> (last_logits, cache)
  decode(params, batch, cache, pos, plan) -> (logits, cache)
  cache_spec(batch, seq)          -> ShapeDtypeStruct pytree
  input_specs(suite)              -> dict[str, ShapeDtypeStruct]
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSuite
from repro.models import losses
from repro.models import transformer as tfm
from repro.sharding.plan import ShardingPlan

Params = Dict[str, Any]


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    cache_spec: Callable[[int, int], Any]
    input_specs: Callable[[ShapeSuite], Dict[str, jax.ShapeDtypeStruct]]

    def param_count(self, params: Optional[Params] = None) -> int:
        from repro.models.module import param_count

        if params is None:
            params = jax.eval_shape(self.init, jax.random.key(0))
        return param_count(params)


# ---------------------------------------------------------------------------
# shared input-spec builders
# ---------------------------------------------------------------------------


def _lm_train_specs(cfg: ModelConfig, suite: ShapeSuite):
    B, S = suite.global_batch, suite.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.n_patches:
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.bfloat16
        )
    if cfg.enc_layers:
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frames, cfg.d_model), jnp.bfloat16
        )
    return specs


def _lm_prefill_specs(cfg: ModelConfig, suite: ShapeSuite):
    specs = _lm_train_specs(cfg, suite)
    specs.pop("labels")
    return specs


def _lm_decode_specs(cfg: ModelConfig, suite: ShapeSuite):
    B = suite.global_batch
    specs = {"token": jax.ShapeDtypeStruct((B,), jnp.int32)}
    if cfg.enc_layers:
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frames, cfg.d_model), jnp.bfloat16
        )
    return specs


def _input_specs(cfg: ModelConfig, suite: ShapeSuite):
    if suite.kind == "train":
        return _lm_train_specs(cfg, suite)
    if suite.kind == "prefill":
        return _lm_prefill_specs(cfg, suite)
    return _lm_decode_specs(cfg, suite)


# ---------------------------------------------------------------------------
# dense / vlm families (transformer.py backbone)
# ---------------------------------------------------------------------------


def _build_dense(cfg: ModelConfig) -> Model:
    def init(key):
        return tfm.init_params(cfg, key)

    def loss(params, batch, plan: ShardingPlan):
        logits = tfm.forward(
            cfg, params, batch["tokens"], plan, patches=batch.get("patches")
        )
        return losses.softmax_cross_entropy(
            logits, batch["labels"], label_smoothing=cfg.label_smoothing
        )

    def prefill(params, batch, plan: ShardingPlan):
        return tfm.prefill(
            cfg, params, batch["tokens"], plan, patches=batch.get("patches")
        )

    def decode(params, batch, cache, pos, plan: ShardingPlan):
        return tfm.decode_step(cfg, params, batch["token"], cache, pos, plan)

    return Model(
        cfg=cfg,
        init=init,
        loss=loss,
        prefill=prefill,
        decode=decode,
        cache_spec=lambda b, s: tfm.cache_spec(cfg, b, s),
        input_specs=lambda suite: _input_specs(cfg, suite),
    )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_BUILDERS: Dict[str, Callable[[ModelConfig], Model]] = {}


def register_family(name: str):
    def deco(fn):
        _BUILDERS[name] = fn
        return fn

    return deco


register_family("dense")(_build_dense)
register_family("vlm")(_build_dense)  # llava backbone = dense + patch stub


def build_model(cfg: ModelConfig) -> Model:
    # late imports so optional families register themselves
    from repro.models import moe as _moe  # noqa: F401
    from repro.models import rwkv6 as _rwkv6  # noqa: F401
    from repro.models import mamba2 as _mamba2  # noqa: F401
    from repro.models import encdec as _encdec  # noqa: F401
    from repro.models import resnet as _resnet  # noqa: F401

    if cfg.family not in _BUILDERS:
        raise KeyError(f"unknown family {cfg.family!r}")
    return _BUILDERS[cfg.family](cfg)
