"""Mixture-of-Experts transformer (deepseek-moe-16b, olmoe-1b-7b).

Expert dispatch is sort-based (megablocks-style): tokens are argsorted by
assigned expert, grouped into a static-capacity (E, C, d) tensor, pushed
through a batched expert GEMM with experts sharded over the ``model`` axis
(expert parallelism), and scatter-added back with their gate weights. This
avoids the O(T*E*C) one-hot dispatch of classic GShard, which is infeasible at
1M-token batches, while staying pure XLA for the 512-device dry-run.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import losses
from repro.models import module as nn
from repro.models import transformer as tfm
from repro.models.model_api import Model, _input_specs, register_family
from repro.sharding.plan import ShardingPlan

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# router + dispatch
# ---------------------------------------------------------------------------


def router_probs(p: Params, x: jax.Array) -> jax.Array:
    """x: (T, d) -> (T, E) f32 softmax probabilities."""
    logits = jnp.einsum(
        "td,de->te", x.astype(jnp.float32), p["w_router"].astype(jnp.float32)
    )
    return jax.nn.softmax(logits, axis=-1), logits


def top_k_gates(probs: jax.Array, k: int, renormalize: bool = True):
    vals, idx = jax.lax.top_k(probs, k)  # (T, k)
    if renormalize:
        vals = vals / jnp.maximum(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)
    return vals, idx


def sort_dispatch(
    x: jax.Array,  # (T, d)
    expert_idx: jax.Array,  # (T, k) int32
    gate_vals: jax.Array,  # (T, k) f32
    n_experts: int,
    capacity: int,
    expert_lo: jax.Array | int = 0,
    n_local: int | None = None,
):
    """Group tokens by expert into (E_local, C, d); returns grouped x + info.

    Tokens beyond an expert's capacity are dropped (capacity_factor-sized).
    ``expert_lo``/``n_local`` restrict dispatch to the local EP shard's
    expert range [expert_lo, expert_lo + n_local): assignments outside it
    are masked out, making the EP combine a pure psum over the model axis.
    vmap-safe (scatter-add instead of bincount).
    """
    if n_local is None:
        n_local = n_experts
    T, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)  # token id per assignment
    flat_g = gate_vals.reshape(-1)

    # stable sort by expert id
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]

    # position of each assignment within its expert's run:
    # pos[i] = i - start_offset[expert[i]]
    counts = jnp.zeros((n_experts,), jnp.int32).at[se].add(1, mode="drop")
    starts = jnp.cumsum(counts) - counts  # (E,)
    pos = jnp.arange(se.shape[0]) - starts[se]
    local_e = se - expert_lo
    keep = (pos < capacity) & (local_e >= 0) & (local_e < n_local)
    local_e = jnp.clip(local_e, 0, n_local - 1)

    slot = local_e * capacity + jnp.where(pos < capacity, pos, 0)  # (T*k,)
    # scatter token rows into the grouped buffer
    grouped = jnp.zeros((n_local * capacity, x.shape[1]), x.dtype)
    grouped = grouped.at[slot].add(
        jnp.where(keep[:, None], x[st], 0).astype(x.dtype), mode="drop"
    )
    grouped = grouped.reshape(n_local, capacity, x.shape[1])
    return grouped, (st, sg, slot, keep)


def sort_combine(
    expert_out: jax.Array,  # (E, C, d)
    scatter_info,
    T: int,
):
    st, sg, slot, keep = scatter_info
    rows = expert_out.reshape(-1, expert_out.shape[-1])[slot]  # (T*k, d)
    rows = rows * (sg * keep.astype(sg.dtype))[:, None].astype(rows.dtype)
    out = jnp.zeros((T, expert_out.shape[-1]), expert_out.dtype)
    return out.at[st].add(rows, mode="drop")


def load_balance_loss(probs: jax.Array, expert_idx: jax.Array, n_experts: int):
    """Switch-style aux loss: E * sum_e fraction_e * mean_prob_e."""
    T = probs.shape[0]
    assign = jnp.zeros((n_experts,), jnp.float32)
    assign = assign.at[expert_idx.reshape(-1)].add(1.0, mode="drop")
    frac = assign / jnp.maximum(jnp.sum(assign), 1.0)
    mean_p = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac * mean_p)


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


def init_moe_ffn(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_expert, m.n_experts
    p = {
        "w_router": nn.fan_in_init(kg(), (d, E), jnp.float32),
        "e_gate": nn.fan_in_init(kg(), (E, d, f), jnp.bfloat16),
        "e_up": nn.fan_in_init(kg(), (E, d, f), jnp.bfloat16),
        "e_down": nn.fan_in_init(
            kg(), (E, f, d), jnp.bfloat16, scale=1.0 / (2 * cfg.n_layers) ** 0.5
        ),
    }
    if m.n_shared:
        fs = m.n_shared * m.d_expert
        p["shared"] = {
            "w_gate": nn.fan_in_init(kg(), (d, fs), jnp.bfloat16),
            "w_up": nn.fan_in_init(kg(), (d, fs), jnp.bfloat16),
            "w_down": nn.fan_in_init(
                kg(), (fs, d), jnp.bfloat16, scale=1.0 / (2 * cfg.n_layers) ** 0.5
            ),
        }
    return p


def _expert_mlp(p: Params, grouped: jax.Array) -> jax.Array:
    """(E, C, d) -> (E, C, d) batched swiglu expert GEMMs."""
    gate_h = jnp.einsum("ecd,edf->ecf", grouped, p["e_gate"].astype(grouped.dtype))
    up_h = jnp.einsum("ecd,edf->ecf", grouped, p["e_up"].astype(grouped.dtype))
    h = jax.nn.silu(gate_h.astype(jnp.float32)).astype(up_h.dtype) * up_h
    return jnp.einsum("ecf,efd->ecd", h, p["e_down"].astype(h.dtype))


def _local_moe(cfg, x, eidx, gates, e_params, capacity, expert_lo, n_local):
    """Per-example dispatch -> expert GEMM -> per-example combine.

    x: (B, S, d). Sorting happens inside each example (vmap over B), so no
    communication crosses examples; only the expert weights are EP-sharded.
    Returns the (partial, if n_local < E) MoE output (B, S, d).
    """
    m = cfg.moe
    B, S, d = x.shape

    def per_example(xe, ee, ge):
        return sort_dispatch(xe, ee, ge, m.n_experts, capacity, expert_lo, n_local)

    grouped, info = jax.vmap(per_example)(x, eidx, gates)  # (B, E_loc, C, d)
    out = jax.vmap(lambda g: _expert_mlp(e_params, g))(grouped)
    y = jax.vmap(lambda o, st, sg, sl, kp: sort_combine(o, (st, sg, sl, kp), S))(
        out, *info
    )
    return y


def moe_ffn(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # (B, S, d)
    plan: ShardingPlan,
    capacity_factor: Optional[float] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Expert-parallel MoE FFN, GSPMD-auto partitioned.

    Routing/top-k/sort/dispatch run *per example* (vmap over B), so every
    gather/scatter is local to the data shard. The grouped (B, E, C, d)
    tensor is then shard-constrained with experts over the ``model`` axis:
    GSPMD turns that reshard into the MoE all-to-all, the expert GEMMs
    contract locally against the (E/tp)-sharded expert weights, and the
    combine reshards back. Wire bytes per layer = 2 grouped-activation
    reshards — the TPU analogue of the NCCL all-to-all dispatch, with no
    manual collectives (a previous shard_map formulation replicated the
    global batch per device; see EXPERIMENTS.md §Perf).
    """
    m = cfg.moe
    if capacity_factor is None:
        capacity_factor = m.capacity_factor
    B, S, d = x.shape
    probs, logits = router_probs(p, x.reshape(B * S, d))
    gates, eidx = top_k_gates(probs, m.top_k)
    gates = gates.reshape(B, S, m.top_k)
    eidx = eidx.reshape(B, S, m.top_k)
    capacity = int(math.ceil(S * m.top_k / m.n_experts * capacity_factor))
    capacity = max(8, -(-capacity // 8) * 8)  # MXU-align the GEMM M-dim

    e_params = {k: p[k] for k in ("e_gate", "e_up", "e_down")}

    def per_example(xe, ee, ge):
        return sort_dispatch(xe, ee, ge, m.n_experts, capacity, 0, m.n_experts)

    grouped, info = jax.vmap(per_example)(x, eidx, gates)  # (B, E, C, d)
    grouped = plan.act(grouped, "grouped")  # experts -> model axis (EP)
    out = jax.vmap(lambda g: _expert_mlp(e_params, g))(grouped)
    out = plan.act(out, "grouped")
    y = jax.vmap(lambda o, st, sg, sl, kp: sort_combine(o, (st, sg, sl, kp), S))(
        out, *info
    )

    if m.n_shared:
        y = y + tfm._mlp(cfg, p["shared"], x, plan)

    aux = {
        "aux_loss": load_balance_loss(probs, eidx.reshape(-1, m.top_k), m.n_experts),
        "router_z": jnp.mean(
            jnp.square(jax.scipy.special.logsumexp(logits, axis=-1))
        ),
    }
    return y, aux


def init_block(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    return {
        "attn_norm": nn.rmsnorm_init(cfg.d_model),
        "attn": tfm.init_attn_layer(cfg, kg()),
        "mlp_norm": nn.rmsnorm_init(cfg.d_model),
        "moe": init_moe_ffn(cfg, kg()),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    return {
        "embed": nn.embedding_init(kg(), cfg.padded_vocab, cfg.d_model),
        "layers": nn.stack_layer_init(
            functools.partial(init_block, cfg), kg(), cfg.n_layers
        ),
        "final_norm": nn.rmsnorm_init(cfg.d_model),
        "lm_head": {"w_lm": nn.fan_in_init(kg(), (cfg.d_model, cfg.padded_vocab), jnp.bfloat16)},
    }


def block_fwd(cfg: ModelConfig, plan: ShardingPlan, carry, lp: Params):
    x, aux_acc = carry
    x = x + tfm._attn_train(cfg, lp["attn"], tfm._norm(cfg, lp["attn_norm"], x), plan)
    x = plan.act(x, "hidden")
    y, aux = moe_ffn(cfg, lp["moe"], tfm._norm(cfg, lp["mlp_norm"], x), plan)
    x = plan.act(x + y, "hidden")
    aux_acc = {
        "aux_loss": aux_acc["aux_loss"] + aux["aux_loss"],
        "router_z": aux_acc["router_z"] + aux["router_z"],
    }
    return x, aux_acc


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, plan: ShardingPlan):
    h = tfm.embed_tokens(cfg, params, tokens, plan)
    aux0 = {"aux_loss": jnp.float32(0), "router_z": jnp.float32(0)}
    body = functools.partial(block_fwd, cfg, plan)
    h, aux = nn.scan_layers(body, (h, aux0), params["layers"], remat=cfg.remat)
    logits = tfm.logits_fn(cfg, params, h, plan)
    return plan.act(logits, "logits"), aux


# ---------------------------------------------------------------------------
# serving path (KV cache identical to dense; MoE FFN applied per step)
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, plan: ShardingPlan):
    B, S = tokens.shape
    h = tfm.embed_tokens(cfg, params, tokens, plan)
    positions = jnp.arange(S)

    def body(x, lp):
        xn = tfm._norm(cfg, lp["attn_norm"], x)
        q, k, v = tfm._qkv(cfg, lp["attn"], xn, plan)
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        kr = nn.apply_rope(k, positions, cfg.rope_theta)
        out = tfm.xla_flash_attention(q, kr, v, causal=True, block_k=cfg.attn_block_k)
        x = x + nn.dense_apply({"w": lp["attn"]["wo"]}, out.reshape(B, S, -1))
        y, _ = moe_ffn(cfg, lp["moe"], tfm._norm(cfg, lp["mlp_norm"], x), plan)
        x = plan.act(x + y, "hidden")
        return x, (kr.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
    cache = {"k": plan.act(ks, "cache"), "v": plan.act(vs, "cache")}
    last = tfm.logits_fn(cfg, params, h[:, -1:, :], plan)[:, 0, :]
    return plan.act(last, "last_logits"), cache


def decode_step(cfg, params, token, cache, pos, plan: ShardingPlan):
    B = token.shape[0]
    h = nn.embedding_apply(params["embed"], token[:, None])
    h = plan.act(h, "decode_hidden")
    pos_arr = jnp.asarray(pos, jnp.int32)

    def body(x, layer_in):
        lp, kc, vc = layer_in
        xn = tfm._norm(cfg, lp["attn_norm"], x)
        q, k, v = tfm._qkv(cfg, lp["attn"], xn, plan)
        q = nn.apply_rope(q, pos_arr[None], cfg.rope_theta)
        k = nn.apply_rope(k, pos_arr[None], cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos_arr, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos_arr, 1)
        from repro.models.attention import decode_attention

        out = decode_attention(q, kc, vc, kv_len=pos_arr + 1)
        x = x + nn.dense_apply({"w": lp["attn"]["wo"]}, out.reshape(B, 1, -1))
        y, _ = moe_ffn(cfg, lp["moe"], tfm._norm(cfg, lp["mlp_norm"], x), plan)
        x = plan.act(x + y, "decode_hidden")
        return x, (kc, vc)

    h, (k_new, v_new) = jax.lax.scan(
        body, h, (params["layers"], cache["k"], cache["v"])
    )
    logits = tfm.logits_fn(cfg, params, h, plan)[:, 0, :]
    return plan.act(logits, "last_logits"), {
        "k": plan.act(k_new, "cache"),
        "v": plan.act(v_new, "cache"),
    }


@register_family("moe")
def _build_moe(cfg: ModelConfig) -> Model:
    def init(key):
        return init_params(cfg, key)

    def loss(params, batch, plan: ShardingPlan):
        logits, aux = forward(cfg, params, batch["tokens"], plan)
        base, metrics = losses.softmax_cross_entropy(logits, batch["labels"])
        m = cfg.moe
        total = (
            base
            + m.router_aux_coef * aux["aux_loss"] / cfg.n_layers
            + m.router_z_coef * aux["router_z"] / cfg.n_layers
        )
        metrics = dict(metrics, aux_loss=aux["aux_loss"] / cfg.n_layers)
        return total, metrics

    return Model(
        cfg=cfg,
        init=init,
        loss=loss,
        prefill=lambda params, batch, plan: prefill(cfg, params, batch["tokens"], plan),
        decode=lambda params, batch, cache, pos, plan: decode_step(
            cfg, params, batch["token"], cache, pos, plan
        ),
        cache_spec=lambda b, s: tfm.cache_spec(cfg, b, s),
        input_specs=lambda suite: _input_specs(cfg, suite),
    )
