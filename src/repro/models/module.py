"""Minimal functional module substrate.

No flax/haiku dependency: parameters are nested dicts (pytrees) of jnp arrays,
built by pure ``init`` functions and consumed by pure ``apply`` functions.
Stacked-layer parameters carry a leading ``L`` dim and are consumed with
``jax.lax.scan`` so the lowered HLO stays O(1) in depth — essential for the
512-device dry-run compiles of 80-layer configs on a single-core host.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]
PRNGKey = jax.Array

# ---------------------------------------------------------------------------
# dtype policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    """Mixed-precision policy: params/compute in bf16, reductions in f32."""

    param_dtype: jnp.dtype = jnp.bfloat16
    compute_dtype: jnp.dtype = jnp.bfloat16
    reduce_dtype: jnp.dtype = jnp.float32


DEFAULT_POLICY = DtypePolicy()
F32_POLICY = DtypePolicy(jnp.float32, jnp.float32, jnp.float32)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def trunc_normal(key: PRNGKey, shape: Sequence[int], std: float, dtype) -> jax.Array:
    """Truncated-normal(±2σ) initializer (the common transformer default)."""
    unscaled = jax.random.truncated_normal(key, -2.0, 2.0, tuple(shape), jnp.float32)
    return (unscaled * std).astype(dtype)


def fan_in_init(key: PRNGKey, shape: Sequence[int], dtype, scale: float = 1.0) -> jax.Array:
    """LeCun-style fan-in init for (in, out)-shaped kernels."""
    fan_in = shape[0] if len(shape) >= 2 else max(int(np.prod(shape)), 1)
    std = scale / math.sqrt(max(fan_in, 1))
    return trunc_normal(key, shape, std, dtype)


def zeros_init(_key: PRNGKey, shape: Sequence[int], dtype) -> jax.Array:
    return jnp.zeros(tuple(shape), dtype)


def ones_init(_key: PRNGKey, shape: Sequence[int], dtype) -> jax.Array:
    return jnp.ones(tuple(shape), dtype)


class KeyGen:
    """Splitting helper so init code reads linearly."""

    def __init__(self, key: PRNGKey):
        self._key = key

    def __call__(self) -> PRNGKey:
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------------------
# primitive layers (init + apply pairs)
# ---------------------------------------------------------------------------


def dense_init(
    key: PRNGKey,
    d_in: int,
    d_out: int,
    *,
    dtype=jnp.bfloat16,
    bias: bool = False,
    scale: float = 1.0,
) -> Params:
    p: Params = {"w": fan_in_init(key, (d_in, d_out), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: Params, x: jax.Array, *, compute_dtype=jnp.bfloat16) -> jax.Array:
    w = p["w"].astype(compute_dtype)
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype), w)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def embedding_init(key: PRNGKey, vocab: int, d: int, *, dtype=jnp.bfloat16) -> Params:
    return {"table": trunc_normal(key, (vocab, d), 1.0 / math.sqrt(d), dtype)}


def embedding_apply(p: Params, ids: jax.Array, *, compute_dtype=jnp.bfloat16) -> jax.Array:
    return jnp.take(p["table"].astype(compute_dtype), ids, axis=0)


def rmsnorm_init(d: int, *, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: Params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(orig_dtype)


def layernorm_init(d: int, *, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p: Params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(orig_dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,), f32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10_000.0
) -> jax.Array:
    """Apply rotary embedding.

    x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).
    Uses the split-halves convention (llama-style).
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# stacked-layer utilities (scan over depth)
# ---------------------------------------------------------------------------


def stack_layer_init(
    layer_init: Callable[[PRNGKey], Params], key: PRNGKey, n_layers: int
) -> Params:
    """Initialize ``n_layers`` copies of a layer and stack leaves on axis 0.

    vmap over the init keeps init time O(1) in tracing cost.
    """
    keys = jax.random.split(key, n_layers)
    return jax.vmap(layer_init)(keys)


def scan_layers(
    body: Callable[[Any, Params], Any],
    carry: Any,
    stacked: Params,
    *,
    remat: bool = False,
    remat_policy: Optional[Callable] = None,
    unroll: int = 1,
):
    """Run ``carry = body(carry, layer_params)`` across the stacked dim with scan."""

    fn = body
    if remat:
        fn = jax.checkpoint(body, policy=remat_policy, prevent_cse=False)

    def step(c, layer_p):
        return fn(c, layer_p), None

    carry, _ = jax.lax.scan(step, carry, stacked, unroll=unroll)
    return carry


def slice_layers(stacked: Params, start: int, stop: int) -> Params:
    """Static python slice of a stacked-params pytree along axis 0."""
    return jax.tree_util.tree_map(lambda a: a[start:stop], stacked)


def param_count(params: Params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))


def param_bytes(params: Params) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(params)
    )


def tree_shapes(params: Params) -> Params:
    return jax.tree_util.tree_map(lambda a: tuple(a.shape), params)


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree_util.tree_map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        params,
    )
