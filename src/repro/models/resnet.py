"""ResNet-V2 (pre-activation) in pure JAX — the paper's workload trio.

resnet_small  = ResNet26-V2  on CIFAR-10-shaped data   (32x32,  10 classes)
resnet_medium = ResNet50-V2  on ImageNet64-shaped data  (64x64,  1000 classes)
resnet_large  = ResNet152-V2 on ImageNet-shaped data    (224x224, 1000 classes)

These are the collocation-study workloads: they run on *instances* produced by
the core partitioner, reproducing the paper's experiment grid. BatchNorm uses
batch statistics (training mode) — running-average eval stats are out of scope
for a throughput/utilization characterization and noted in DESIGN.md.

Convolution layers are heterogeneous across stages, so depth is unrolled
python-side (stage structure is static and small) rather than scanned.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSuite
from repro.models import module as nn
from repro.models.model_api import Model, register_family
from repro.sharding.plan import ShardingPlan

Params = Dict[str, Any]

_DN = ("NHWC", "HWIO", "NHWC")


def conv_init(key, kh, kw, cin, cout, dtype=jnp.float32) -> Params:
    fan_in = kh * kw * cin
    std = (2.0 / fan_in) ** 0.5  # He init
    return {"w": nn.trunc_normal(key, (kh, kw, cin, cout), std, dtype)}


def conv_apply(p: Params, x: jax.Array, stride: int = 1) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x,
        p["w"].astype(x.dtype),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=_DN,
    )


def bn_init(c: int) -> Params:
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def bn_apply(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(xf, axis=(0, 1, 2), keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def _bottleneck_init(kg, cin: int, width: int, cout: int) -> Params:
    p = {
        "bn1": bn_init(cin),
        "conv1": conv_init(kg(), 1, 1, cin, width),
        "bn2": bn_init(width),
        "conv2": conv_init(kg(), 3, 3, width, width),
        "bn3": bn_init(width),
        "conv3": conv_init(kg(), 1, 1, width, cout),
    }
    if cin != cout:
        p["proj"] = conv_init(kg(), 1, 1, cin, cout)
    return p


def _bottleneck_apply(p: Params, x: jax.Array, stride: int) -> jax.Array:
    pre = jax.nn.relu(bn_apply(p["bn1"], x))
    shortcut = conv_apply(p["proj"], pre, stride) if "proj" in p else x
    if "proj" not in p and stride > 1:
        shortcut = x[:, ::stride, ::stride, :]
    h = conv_apply(p["conv1"], pre, 1)
    h = conv_apply(p["conv2"], jax.nn.relu(bn_apply(p["bn2"], h)), stride)
    h = conv_apply(p["conv3"], jax.nn.relu(bn_apply(p["bn3"], h)), 1)
    return shortcut + h


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    w0 = cfg.base_width
    cifar_stem = cfg.img_size <= 32
    params: Params = {
        "stem": conv_init(kg(), 3 if cifar_stem else 7, 3 if cifar_stem else 7, 3, w0)
    }
    cin = w0
    blocks = []
    for stage, n_blocks in enumerate(cfg.stages):
        width = w0 * (2**stage)
        cout = width * 4
        for b in range(n_blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            blocks.append(
                {
                    "p": _bottleneck_init(kg, cin, width, cout),
                    "stride": stride,
                }
            )
            cin = cout
    params["blocks"] = [b["p"] for b in blocks]
    params["final_bn"] = bn_init(cin)
    params["head"] = nn.dense_init(kg(), cin, cfg.n_classes, dtype=jnp.float32)
    return params


def _block_strides(cfg: ModelConfig) -> Tuple[int, ...]:
    strides = []
    for stage, n_blocks in enumerate(cfg.stages):
        for b in range(n_blocks):
            strides.append(2 if (b == 0 and stage > 0) else 1)
    return tuple(strides)


def forward(cfg: ModelConfig, params: Params, images: jax.Array, plan: ShardingPlan):
    """images: (B, H, W, 3) f32 -> logits (B, n_classes)."""
    cifar_stem = cfg.img_size <= 32
    x = conv_apply(params["stem"], images.astype(jnp.float32), 1 if cifar_stem else 2)
    if not cifar_stem:
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
        )
    for p, stride in zip(params["blocks"], _block_strides(cfg)):
        x = _bottleneck_apply(p, x, stride)
        x = plan.act(x, "hidden") if x.ndim == 3 else x
    x = jax.nn.relu(bn_apply(params["final_bn"], x))
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    return nn.dense_apply(params["head"], x, compute_dtype=jnp.float32)


def _image_specs(cfg: ModelConfig, suite: ShapeSuite):
    B = suite.global_batch
    s = cfg.img_size
    return {
        "images": jax.ShapeDtypeStruct((B, s, s, 3), jnp.float32),
        "labels": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


@register_family("resnet")
def _build_resnet(cfg: ModelConfig) -> Model:
    def loss(params, batch, plan: ShardingPlan):
        logits = forward(cfg, params, batch["images"], plan)
        labels = batch["labels"]
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        nll = lse - jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
        acc = jnp.mean((jnp.argmax(lf, axis=-1) == labels).astype(jnp.float32))
        return jnp.mean(nll), {"ce": jnp.mean(nll), "accuracy": acc}

    def _no_serve(*_a, **_k):
        raise NotImplementedError("CNN classifier has no autoregressive serving path")

    return Model(
        cfg=cfg,
        init=lambda key: init_params(cfg, key),
        loss=loss,
        prefill=_no_serve,
        decode=_no_serve,
        cache_spec=lambda b, s: {},
        input_specs=lambda suite: _image_specs(cfg, suite),
    )
