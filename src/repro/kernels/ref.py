"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (shape/dtype
sweeps with assert_allclose under ``interpret=True``). They are deliberately
naive — O(S^2) attention materializing the score matrix, token-by-token WKV
recurrence — because clarity is the point of an oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_reference(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, KVH, D)
    v: jax.Array,  # (B, Skv, KVH, D)
    *,
    causal: bool = True,
    q_offset: int = 0,
    scale: float | None = None,
) -> jax.Array:
    """Naive GQA attention: full (Sq, Skv) score matrix, f32 softmax."""
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = D**-0.5 if scale is None else scale
    qf = q.reshape(B, Sq, KVH, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32))
    if causal:
        q_pos = q_offset + jnp.arange(Sq)
        mask = q_pos[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention_reference(
    q: jax.Array,  # (B, H, D) single query token
    k_cache: jax.Array,  # (B, Smax, KVH, D)
    v_cache: jax.Array,  # (B, Smax, KVH, D)
    *,
    kv_len: jax.Array | int,
    scale: float | None = None,
) -> jax.Array:
    """Single-token decode attention against a (masked) KV cache."""
    B, H, D = q.shape
    _, Smax, KVH, _ = k_cache.shape
    G = H // KVH
    scale = D**-0.5 if scale is None else scale
    qf = q.reshape(B, KVH, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    pos = jnp.arange(Smax)
    s = jnp.where(pos[None, None, None, :] < kv_len, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def wkv6_reference(
    r: jax.Array,  # (B, T, H, K)
    k: jax.Array,  # (B, T, H, K)
    v: jax.Array,  # (B, T, H, V)
    logw: jax.Array,  # (B, T, H, K) log-decay <= 0
    u: jax.Array,  # (H, K) bonus
    state0: jax.Array,  # (B, H, K, V)
):
    """Token-by-token WKV6 recurrence (RWKV-6 'Finch'):

        o_t = r_t @ (S_{t-1} + (u * k_t) v_t^T)
        S_t = diag(exp(logw_t)) S_{t-1} + k_t v_t^T

    Returns (out (B,T,H,V) f32, final state (B,H,K,V) f32).
    """
    rf = r.astype(jnp.float32).swapaxes(0, 1)  # (T, B, H, K)
    kf = k.astype(jnp.float32).swapaxes(0, 1)
    vf = v.astype(jnp.float32).swapaxes(0, 1)
    wf = logw.astype(jnp.float32).swapaxes(0, 1)
    uf = u.astype(jnp.float32)

    def step(S, inputs):
        rt, kt, vt, wt = inputs  # (B,H,K/V)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + uf[None, :, :, None] * kv)
        S = jnp.exp(wt)[..., None] * S + kv
        return S, out

    state, outs = jax.lax.scan(step, state0.astype(jnp.float32), (rf, kf, vf, wf))
    return outs.swapaxes(0, 1), state
