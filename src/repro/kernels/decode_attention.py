"""Single-query decode attention over a long KV cache — Pallas TPU kernel.

The decode_32k / long_500k serving cells attend one new token against a
seq_len-deep cache: the op is *memory-bound* (arithmetic intensity
≈ 2 FLOPs/byte « the 240 FLOP/byte ridge), so the kernel is shaped around
HBM→VMEM streaming, not MXU occupancy:

  * grid (B, KVH, nk) with the KV dim innermost: each (batch, kv-head)
    streams its KV stripe block-by-block through VMEM exactly once while the
    (G, D) query tile and the f32 accumulator stay resident;
  * ``block_k`` is sized so two KV blocks (k + v, bf16) fit VMEM alongside
    the accumulator, letting the implicit Pallas double-buffering overlap
    the next block's DMA with the current block's compute;
  * the dynamic valid length (``kv_len``, a traced scalar) rides in SMEM as
    a scalar-prefetch operand and masks the tail block.

Validated against ``ref.decode_attention_reference`` in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _decode_kernel(
    kv_len_ref,  # SMEM (1,) int32 — scalar prefetch
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, block_k, 1, D)
    v_ref,  # (1, block_k, 1, D)
    o_ref,  # (1, 1, G, D)
    acc,  # VMEM (G, D) f32
    m,  # VMEM (G, LANES) f32
    l,  # VMEM (G, LANES) f32
    *,
    scale: float,
    block_k: int,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    kv_len = kv_len_ref[0]

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    # skip blocks entirely beyond the valid cache length
    @pl.when(ik * block_k < kv_len)
    def _compute():
        G, D = q_ref.shape[2], q_ref.shape[3]
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
        k = k_ref[:, :, 0, :][0].astype(jnp.float32)  # (block_k, D)
        v = v_ref[:, :, 0, :][0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, block_k)
        kv_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (G, block_k), 1
        )
        s = jnp.where(kv_pos < kv_len, s, NEG_INF)

        m_prev = m[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l[...] = jnp.broadcast_to(
            l[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True), l.shape
        )
        acc[...] = acc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m[...] = jnp.broadcast_to(m_new, m.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l[:, :1], 1e-30)).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # (B, H, D) one new token per sequence
    k_cache: jax.Array,  # (B, Smax, KVH, D)
    v_cache: jax.Array,  # (B, Smax, KVH, D)
    kv_len: jax.Array,  # scalar int32 — valid cache entries
    *,
    scale: Optional[float] = None,
    block_k: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, H, D) attention output in q.dtype."""
    B, H, D = q.shape
    _, Smax, KVH, _ = k_cache.shape
    G = H // KVH
    scale = D**-0.5 if scale is None else scale
    block_k = min(block_k, Smax)
    if Smax % block_k:
        raise ValueError(f"Smax={Smax} must divide block_k={block_k}")
    nk = Smax // block_k

    qr = q.reshape(B, KVH, G, D)
    kv_len_arr = jnp.asarray(kv_len, jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KVH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik, *_: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik, *_: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        interpret=interpret,
    )(kv_len_arr, qr, k_cache, v_cache)
    return out.reshape(B, H, D)
