"""jit'd public wrappers around the Pallas kernels.

Layout adaptation (model API uses (B, S, H, D); kernels use the GQA-folded
(B, KVH, S, G, D)), custom_vjp wiring for training, and the execution-mode
switch:

  * ``mode='tpu'``       — compiled Pallas (the deployment path)
  * ``mode='interpret'`` — Pallas interpret=True (CPU correctness runs;
                           this is what the test suite sweeps)
  * ``mode='ref'``       — the pure-jnp oracle (debugging / oracles)
  * ``mode=None``        — auto: TPU backend -> 'tpu', else 'ref' (XLA path
                           stays the CPU-dry-run default so 512-device
                           lowering never pays interpret-mode cost)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import decode_attention as da
from repro.kernels import rwkv6_scan as rk
from repro.kernels import ref


def _auto_mode(mode: Optional[str]) -> str:
    if mode is not None:
        return mode
    return "tpu" if jax.default_backend() == "tpu" else "ref"


# ---------------------------------------------------------------------------
# flash attention (training: fwd + bwd kernels under custom_vjp)
# ---------------------------------------------------------------------------


def _fold(q, kvh):
    """(B, S, H, D) -> (B, KVH, S, G, D)."""
    B, S, H, D = q.shape
    return q.reshape(B, S, kvh, H // kvh, D).transpose(0, 2, 1, 3, 4)


def _unfold(qf):
    """(B, KVH, S, G, D) -> (B, S, H, D)."""
    B, KVH, S, G, D = qf.shape
    return qf.transpose(0, 2, 1, 3, 4).reshape(B, S, KVH * G, D)


def _kv_fold(k):
    """(B, S, KVH, D) -> (B, KVH, S, D)."""
    return k.transpose(0, 2, 1, 3)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def _flash(q, k, v, causal, scale, block_q, block_k, interpret):
    o, _ = fa.flash_attention_fwd(
        q, k, v, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, interpret=interpret,
    )
    return o


def _flash_fwd(q, k, v, causal, scale, block_q, block_k, interpret):
    o, lse = fa.flash_attention_fwd(
        q, k, v, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, interpret=interpret,
    )
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, scale, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = fa.flash_attention_bwd(
        q, k, v, o, lse, do, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, KVH, D)
    v: jax.Array,  # (B, Skv, KVH, D)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    mode: Optional[str] = None,
) -> jax.Array:
    """GQA flash attention with the model-API layout. Differentiable."""
    mode = _auto_mode(mode)
    if mode == "ref":
        return ref.mha_reference(q, k, v, causal=causal, scale=scale)
    D = q.shape[-1]
    scale = D**-0.5 if scale is None else scale
    KVH = k.shape[2]
    qf = _fold(q, KVH)
    o = _flash(
        qf, _kv_fold(k), _kv_fold(v), causal, scale,
        block_q, block_k, mode == "interpret",
    )
    return _unfold(o)


def decode_attention(
    q: jax.Array,  # (B, 1, H, D) or (B, H, D)
    k_cache: jax.Array,  # (B, Smax, KVH, D)
    v_cache: jax.Array,  # (B, Smax, KVH, D)
    *,
    kv_len,
    scale: Optional[float] = None,
    block_k: int = 1024,
    mode: Optional[str] = None,
) -> jax.Array:
    """Single-token decode attention; returns q-shaped output."""
    mode = _auto_mode(mode)
    squeeze = q.ndim == 4
    q3 = q[:, 0] if squeeze else q
    if mode == "ref":
        out = ref.decode_attention_reference(
            q3, k_cache, v_cache, kv_len=kv_len, scale=scale
        )
    else:
        out = da.decode_attention(
            q3, k_cache, v_cache, jnp.asarray(kv_len, jnp.int32),
            scale=scale, block_k=block_k, interpret=mode == "interpret",
        )
    return out[:, None] if squeeze else out


def wkv6(
    r, k, v, logw, u, state0,
    *,
    chunk: int = 64,
    mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV6 scan; returns (out (B,T,H,V) f32, state (B,H,K,V) f32)."""
    mode = _auto_mode(mode)
    if mode == "ref":
        return ref.wkv6_reference(r, k, v, logw, u, state0)
    return rk.wkv6_scan(
        r, k, v, logw, u, state0, chunk=chunk, interpret=mode == "interpret"
    )
