"""Chunked WKV6 (RWKV-6 'Finch') linear-attention scan — Pallas TPU kernel.

The recurrence (per head, state S in R^{KxV}, data-dependent decay w_t):

    o_t = r_t @ (S_{t-1} + (u * k_t) v_t^T)
    S_t = diag(exp(logw_t)) S_{t-1} + k_t v_t^T

is the attention-free hot spot of the assigned pool. A token-by-token scan
is latency-bound (T sequential steps of rank-1 updates); the kernel instead
uses the chunked form: inside a chunk of C tokens the recurrence expands to
a bounded pairwise sum (every exponent is a *difference of cumulative
log-decays*, hence <= 0 — overflow-safe in f32, unlike the factored
(r e^{+cum}) @ (k e^{-cum})^T form which overflows once |cum| > 88), and
chunk-to-chunk state is carried in VMEM.

TPU mapping:
  * grid (B, H, n_chunks), chunk dim innermost: the (K, V) f32 state lives
    in VMEM scratch across the whole chunk sweep — zero HBM state traffic;
  * intra-chunk work is two MXU matmuls ((C,K)x(K,V) cross-chunk term,
    (C,C)x(C,V) pairwise term) plus VPU elementwise decay algebra;
  * the (C, C, K) pairwise-decay tensor is the VMEM budget knob:
    C=64, K=64 -> 1 MiB f32, leaving room for double-buffered r/k/v/w tiles.

Validated against ``ref.wkv6_reference`` in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(
    r_ref,  # (1, C, 1, K)
    k_ref,  # (1, C, 1, K)
    v_ref,  # (1, C, 1, V)
    w_ref,  # (1, C, 1, K) log-decay <= 0
    u_ref,  # (1, K)
    s0_ref,  # (1, 1, K, V) initial state
    o_ref,  # (1, C, 1, V)
    sT_ref,  # (1, 1, K, V) final state
    S,  # VMEM (K, V) f32 carried state
    *,
    chunk: int,
):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        S[...] = s0_ref[0, 0].astype(jnp.float32)

    C = chunk
    r = r_ref[0, :, 0, :].astype(jnp.float32)  # (C, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (C, V)
    w = w_ref[0, :, 0, :].astype(jnp.float32)  # (C, K), <= 0
    u = u_ref[0].astype(jnp.float32)  # (K,)

    clw = jnp.cumsum(w, axis=0)  # inclusive cumulative log-decay
    clw_ex = clw - w  # exclusive

    # pairwise decay for s < t: exp(clw_ex[t] - clw[s]) (<= 0 exponent)
    diff = clw_ex[:, None, :] - clw[None, :, :]  # (C, C, K)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    strict = t_idx > s_idx  # strictly lower triangular
    decay = jnp.exp(jnp.where(strict[:, :, None], diff, -jnp.inf))  # (C,C,K)

    # scores[t,s] = sum_k r[t,k] k[s,k] decay[t,s,k]
    scores = jnp.sum(r[:, None, :] * k[None, :, :] * decay, axis=-1)  # (C,C)
    out = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, V)
    # diagonal bonus: (r_t . (u * k_t)) v_t
    out += jnp.sum(r * k * u[None, :], axis=-1, keepdims=True) * v
    # cross-chunk: r decayed to chunk start @ carried state
    out += jax.lax.dot_general(
        r * jnp.exp(clw_ex), S[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)

    # state update: S' = exp(clw[-1]) * S + sum_s (k_s e^{clw[-1]-clw[s]}) v_s^T
    last = clw[-1:, :]  # (1, K)
    kdec = k * jnp.exp(last - clw)  # (C, K)
    S[...] = jnp.exp(last[0])[:, None] * S[...] + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ic == nc - 1)
    def _finalize():
        sT_ref[0, 0] = S[...]


def wkv6_scan(
    r: jax.Array,  # (B, T, H, K)
    k: jax.Array,  # (B, T, H, K)
    v: jax.Array,  # (B, T, H, V)
    logw: jax.Array,  # (B, T, H, K) log-decay <= 0
    u: jax.Array,  # (H, K) bonus
    state0: jax.Array,  # (B, H, K, V)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,T,H,V) f32, final state (B,H,K,V) f32)."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, T)
    if T % chunk:
        raise ValueError(f"T={T} must be divisible by chunk={chunk}")
    nc = T // chunk

    grid = (B, H, nc)
    seq_spec_k = pl.BlockSpec((1, chunk, 1, K), lambda b, h, ic: (b, ic, h, 0))
    seq_spec_v = pl.BlockSpec((1, chunk, 1, V), lambda b, h, ic: (b, ic, h, 0))
    state_spec = pl.BlockSpec((1, 1, K, V), lambda b, h, ic: (b, h, 0, 0))

    out, state = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            seq_spec_k,
            seq_spec_k,
            seq_spec_v,
            seq_spec_k,
            pl.BlockSpec((1, K), lambda b, h, ic: (h, 0)),
            state_spec,
        ],
        out_specs=[seq_spec_v, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, V), jnp.float32),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, state0)
    return out, state
