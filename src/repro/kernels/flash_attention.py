"""Blocked causal GQA flash attention — Pallas TPU kernels (fwd + bwd).

TPU adaptation notes (DESIGN.md §2): the CUDA flash-attention algorithm keys
on warp-level tiling and shared-memory banking; on TPU the same online-
softmax recurrence is re-tiled for the MXU and VMEM:

  * the G query heads sharing one KV head are FOLDED into the row dim of the
    q tile, so the score matmul is a single (Bq*G, D) x (D, Bk) MXU op —
    GQA comes for free instead of a per-head loop;
  * the grid is (B, KVH, nq, nk) with the KV dim innermost: TPU grid
    execution is sequential over the last axis, so the f32 accumulator and
    the online-softmax stats (m, l) live in VMEM scratch across the KV
    sweep of each q tile — the HBM traffic is exactly one read of q/k/v and
    one write of o per tile;
  * softmax stats are kept as (rows, 128) lane-replicated tiles (VREG-
    friendly broadcast instead of (rows, 1) relayouts);
  * causal q-tiles skip fully-masked KV tiles via ``pl.when`` on the grid
    index (≈2x fewer MXU ops at long seq).

Backward follows the two-kernel FlashAttention-2 schedule: a dk/dv kernel
with the q dim innermost, and a dq kernel with the KV dim innermost; both
recompute p from (q, k, lse) so no S x S tensor ever exists.

Validated against ``ref.mha_reference`` in interpret mode (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128  # stat tiles are lane-replicated to this width


def _row_positions(block_q: int, g: int, iq, q_offset: int):
    """Absolute q position of each folded (q, g) row: row -> q index."""
    rows = block_q * g
    r = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
    return q_offset + iq * block_q + r // g  # (rows, 1)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(
    q_ref,  # (1, 1, Bq, G, D)
    k_ref,  # (1, 1, Bk, D)
    v_ref,  # (1, 1, Bk, D)
    o_ref,  # (1, 1, Bq, G, D)
    lse_ref,  # (1, 1, Bq, G)
    acc,  # VMEM (Bq*G, D) f32
    m,  # VMEM (Bq*G, LANES) f32
    l,  # VMEM (Bq*G, LANES) f32
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    g: int,
    kv_valid: int,
    q_offset: int,
):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)
    rows = block_q * g

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    # causal: skip KV tiles strictly above the diagonal of this q tile
    q_hi = q_offset + (iq + 1) * block_q - 1  # last q position in tile
    live = (ik * block_k <= q_hi) if causal else (ik >= 0)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].reshape(rows, q_ref.shape[-1]).astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)  # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (Bk, D)
        s = jax.lax.dot_general(
            q * scale, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (rows, Bk)

        kv_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_k), 1
        )
        mask = kv_pos < kv_valid
        if causal:
            mask &= _row_positions(block_q, g, iq, q_offset) >= kv_pos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m[:, :1]  # (rows, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (rows, Bk)
        corr = jnp.exp(m_prev - m_new)  # (rows, 1)
        l_new = l[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc[...] = acc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m[...] = jnp.broadcast_to(m_new, m.shape)
        l[...] = jnp.broadcast_to(l_new, l.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        lsum = l[:, :1]
        out = acc[...] / jnp.maximum(lsum, 1e-30)
        o_ref[0, 0] = out.reshape(o_ref.shape[2:]).astype(o_ref.dtype)
        lse = (m[:, :1] + jnp.log(jnp.maximum(lsum, 1e-30))).reshape(
            block_q, g
        )
        lse_ref[0, 0] = lse


def flash_attention_fwd(
    q: jax.Array,  # (B, KVH, Sq, G, D)
    k: jax.Array,  # (B, KVH, Skv, D)
    v: jax.Array,  # (B, KVH, Skv, D)
    *,
    causal: bool,
    scale: float,
    block_q: int = 512,
    block_k: int = 512,
    q_offset: int = 0,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (o (B,KVH,Sq,G,D), lse (B,KVH,Sq,G) f32)."""
    B, KVH, Sq, G, D = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    nq = -(-Sq // block_q)
    nk = -(-Skv // block_k)
    if Sq % block_q or Skv % block_k:
        raise ValueError(f"seq ({Sq},{Skv}) must divide blocks ({block_q},{block_k})")

    grid = (B, KVH, nq, nk)
    kernel = functools.partial(
        _fwd_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        g=G,
        kv_valid=Skv,
        q_offset=q_offset,
    )
    rows = block_q * G
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, G, D), lambda b, h, iq, ik: (b, h, iq, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, G, D), lambda b, h, iq, ik: (b, h, iq, 0, 0)),
            pl.BlockSpec((1, 1, block_q, G), lambda b, h, iq, ik: (b, h, iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KVH, Sq, G, D), q.dtype),
            jax.ShapeDtypeStruct((B, KVH, Sq, G), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, D), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# backward: dk/dv kernel (q innermost), dq kernel (kv innermost)
# ---------------------------------------------------------------------------


def _bwd_dkv_kernel(
    q_ref,  # (1, 1, Bq, G, D)
    k_ref,  # (1, 1, Bk, D)
    v_ref,  # (1, 1, Bk, D)
    do_ref,  # (1, 1, Bq, G, D)
    lse_ref,  # (1, 1, Bq, G)
    delta_ref,  # (1, 1, Bq, G)
    dk_ref,  # (1, 1, Bk, D)
    dv_ref,  # (1, 1, Bk, D)
    dk_acc,  # VMEM (Bk, D) f32
    dv_acc,  # VMEM (Bk, D) f32
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    g: int,
    kv_valid: int,
    q_offset: int,
):
    ik, iq = pl.program_id(2), pl.program_id(3)
    nq = pl.num_programs(3)
    rows = block_q * g

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_hi = q_offset + (iq + 1) * block_q - 1
    live = (ik * block_k <= q_hi) if causal else (ik >= 0)

    @pl.when(live)
    def _compute():
        D = q_ref.shape[-1]
        q = q_ref[0, 0].reshape(rows, D).astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].reshape(rows, D).astype(jnp.float32)
        lse = lse_ref[0, 0].reshape(rows, 1)
        delta = delta_ref[0, 0].reshape(rows, 1)

        s = jax.lax.dot_general(
            q * scale, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        kv_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_k), 1
        )
        mask = kv_pos < kv_valid
        if causal:
            mask &= _row_positions(block_q, g, iq, q_offset) >= kv_pos
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse)  # (rows, Bk) — true softmax probs
        # dv += p^T @ do
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        # ds = p * (do @ v^T - delta); dk += ds^T @ q * scale
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta)
        dk_acc[...] += scale * jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(iq == nq - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(
    q_ref,  # (1, 1, Bq, G, D)
    k_ref,  # (1, 1, Bk, D)
    v_ref,  # (1, 1, Bk, D)
    do_ref,  # (1, 1, Bq, G, D)
    lse_ref,  # (1, 1, Bq, G)
    delta_ref,  # (1, 1, Bq, G)
    dq_ref,  # (1, 1, Bq, G, D)
    dq_acc,  # VMEM (Bq*G, D) f32
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    g: int,
    kv_valid: int,
    q_offset: int,
):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)
    rows = block_q * g

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_hi = q_offset + (iq + 1) * block_q - 1
    live = (ik * block_k <= q_hi) if causal else (ik >= 0)

    @pl.when(live)
    def _compute():
        D = q_ref.shape[-1]
        q = q_ref[0, 0].reshape(rows, D).astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].reshape(rows, D).astype(jnp.float32)
        lse = lse_ref[0, 0].reshape(rows, 1)
        delta = delta_ref[0, 0].reshape(rows, 1)

        s = jax.lax.dot_general(
            q * scale, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        kv_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_k), 1
        )
        mask = kv_pos < kv_valid
        if causal:
            mask &= _row_positions(block_q, g, iq, q_offset) >= kv_pos
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta)
        dq_acc[...] += scale * jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ik == nk - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].reshape(dq_ref.shape[2:]).astype(dq_ref.dtype)


def flash_attention_bwd(
    q, k, v, o, lse, do,
    *,
    causal: bool,
    scale: float,
    block_q: int = 512,
    block_k: int = 512,
    q_offset: int = 0,
    interpret: bool = False,
):
    """Returns (dq, dk, dv) with the layouts of (q, k, v)."""
    B, KVH, Sq, G, D = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    nq, nk = Sq // block_q, Skv // block_k

    # delta[b,h,t,g] = sum_d do * o — the rowwise correction term
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)

    common = dict(
        scale=scale, causal=causal, block_q=block_q, block_k=block_k,
        g=G, kv_valid=Skv, q_offset=q_offset,
    )
    rows = block_q * G

    dkv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, **common),
        grid=(B, KVH, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, G, D), lambda b, h, ik, iq: (b, h, iq, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, iq: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, iq: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_q, G, D), lambda b, h, ik, iq: (b, h, iq, 0, 0)),
            pl.BlockSpec((1, 1, block_q, G), lambda b, h, ik, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q, G), lambda b, h, ik, iq: (b, h, iq, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, iq: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, iq: (b, h, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, D), jnp.float32),
            pltpu.VMEM((block_k, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, **common),
        grid=(B, KVH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, G, D), lambda b, h, iq, ik: (b, h, iq, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_q, G, D), lambda b, h, iq, ik: (b, h, iq, 0, 0)),
            pl.BlockSpec((1, 1, block_q, G), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q, G), lambda b, h, iq, ik: (b, h, iq, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, G, D), lambda b, h, iq, ik: (b, h, iq, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(q.shape, q.dtype)],
        scratch_shapes=[pltpu.VMEM((rows, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)[0]

    return dq, dkv[0], dkv[1]
