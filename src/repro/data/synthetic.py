"""Deterministic synthetic datasets, shape-faithful to the paper's workloads.

The paper trains on CIFAR-10 / ImageNet64x64 / ImageNet2012; this container
has no datasets, so each is replaced by a seeded generator producing batches
of identical shape, dtype, cardinality and (approximate) statistics. The
determinism contract — ``batch(epoch, step)`` is a pure function of
(seed, epoch, step) — is what checkpoint-resume and the elastic repack rely
on: a job restarted on a different instance replays the exact same stream.

LM token streams serve the assigned-architecture training examples the same
way.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Cardinality + shape metadata for one synthetic dataset."""

    name: str
    n_train: int
    n_val: int
    image_size: int = 0  # images: H=W
    n_classes: int = 0
    vocab: int = 0  # LM streams
    seq_len: int = 0


# the paper's datasets (§3.3.1)
CIFAR10 = DatasetSpec("cifar10", 45_000, 5_000, image_size=32, n_classes=10)
IMAGENET64 = DatasetSpec("imagenet64", 1_281_167, 50_000, image_size=64, n_classes=1000)
IMAGENET224 = DatasetSpec("imagenet224", 1_281_167, 50_000, image_size=224, n_classes=1000)

DATASETS = {d.name: d for d in (CIFAR10, IMAGENET64, IMAGENET224)}

FOR_WORKLOAD = {
    "resnet_small": CIFAR10,
    "resnet_medium": IMAGENET64,
    "resnet_large": IMAGENET224,
}


def _rng(seed: int, epoch: int, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, epoch, step])
    )


def image_batch(
    spec: DatasetSpec, batch: int, *, seed: int = 0, epoch: int = 0, step: int = 0
) -> Dict[str, np.ndarray]:
    """One (images, labels) batch: N(0,1) pixels (mean-subtracted, like the
    paper's preprocessing), uniform labels."""
    g = _rng(seed, epoch, step)
    s = spec.image_size
    return {
        "images": g.standard_normal((batch, s, s, 3), dtype=np.float32),
        "labels": g.integers(0, spec.n_classes, (batch,), dtype=np.int32),
    }


def token_batch(
    vocab: int, batch: int, seq_len: int, *, seed: int = 0, epoch: int = 0,
    step: int = 0, extras: Optional[Dict[str, Tuple[Tuple[int, ...], str]]] = None,
) -> Dict[str, np.ndarray]:
    """LM (tokens, labels) batch; labels are tokens shifted by one (next-token
    prediction over a deterministic pseudo-corpus)."""
    g = _rng(seed, epoch, step)
    stream = g.integers(0, vocab, (batch, seq_len + 1), dtype=np.int32)
    out = {"tokens": stream[:, :-1], "labels": stream[:, 1:]}
    for name, (shape, dtype) in (extras or {}).items():
        out[name] = g.standard_normal(shape, dtype=np.float32).astype(dtype)
    return out


def batch_for(model_cfg, suite, *, seed: int = 0, epoch: int = 0, step: int = 0):
    """Shape-correct batch for any (config, suite) — mirrors input_specs."""
    if model_cfg.family == "resnet":
        spec = FOR_WORKLOAD.get(
            model_cfg.name,
            DatasetSpec("custom", 45_000, 5_000, model_cfg.img_size, model_cfg.n_classes),
        )
        return image_batch(spec, suite.global_batch, seed=seed, epoch=epoch, step=step)
    extras = {}
    B = suite.global_batch
    if model_cfg.n_patches:
        extras["patches"] = ((B, model_cfg.n_patches, model_cfg.d_model), "bfloat16")
    if model_cfg.enc_layers:
        extras["frames"] = ((B, model_cfg.n_frames, model_cfg.d_model), "bfloat16")
    return token_batch(
        model_cfg.vocab, B, suite.seq_len,
        seed=seed, epoch=epoch, step=step, extras=extras,
    )


def steps_per_epoch(spec: DatasetSpec, batch: int) -> int:
    return -(-spec.n_train // batch)


def epoch_iterator(
    spec: DatasetSpec, model_cfg, suite, *, seed: int = 0, epoch: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    for step in range(steps_per_epoch(spec, suite.global_batch)):
        yield batch_for(model_cfg, suite, seed=seed, epoch=epoch, step=step)
