"""Host-side input pipeline with the paper's §3.3.1 knobs.

The paper tunes TensorFlow's ``ImageDataGenerator`` with two parameters —
``workers`` (CPU threads producing preprocessed batches) and
``max_queue_size`` (bounded RAM queue of ready batches) — until Tensorboard
shows near-zero input-wait. This module is the JAX-native equivalent:

  * ``HostPipeline`` runs ``workers`` daemon threads, each materializing
    deterministic synthetic batches (data/synthetic.py) into a bounded
    queue of ``max_queue_size`` — batches are claimed by step index so the
    stream order is deterministic regardless of thread interleaving;
  * per-batch *wait time* is measured on the consumer side — the same
    "time spent on input" signal the paper minimized; ``stats()`` exposes
    it so the F7 host-side findings (n collocated jobs -> n x CPU, n x RAM)
    can be benchmarked;
  * queue memory is accounted analytically (bytes per buffered batch x
    ``max_queue_size``), reproducing the paper's RAM-vs-parallelism trade.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class HostPipeline:
    """Bounded multi-worker prefetch pipeline over a deterministic source."""

    def __init__(
        self,
        make_batch: Callable[[int], Dict[str, np.ndarray]],  # step -> batch
        *,
        workers: int = 1,
        max_queue_size: int = 10,
        start_step: int = 0,
    ):
        self.make_batch = make_batch
        self.workers = workers
        self.max_queue_size = max_queue_size
        self._q: "queue.Queue[tuple[int, dict]]" = queue.Queue(maxsize=max_queue_size)
        self._next_to_produce = start_step
        self._next_to_consume = start_step
        self._produce_lock = threading.Lock()
        self._stop = threading.Event()
        self._threads = []
        self._wait_s = 0.0
        self._batches = 0
        self._stash: Dict[int, dict] = {}
        self._stash_lock = threading.Lock()

    # -- worker side ---------------------------------------------------------

    def _claim_step(self) -> int:
        with self._produce_lock:
            s = self._next_to_produce
            self._next_to_produce += 1
            return s

    def _worker(self):
        while not self._stop.is_set():
            step = self._claim_step()
            batch = self.make_batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def start(self) -> "HostPipeline":
        for _ in range(self.workers):
            t = threading.Thread(target=self._worker, daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def stop(self):
        self._stop.set()
        # drain so blocked producers exit
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- consumer side ---------------------------------------------------------

    def get(self) -> Dict[str, np.ndarray]:
        """Next batch in deterministic step order; measures input-wait."""
        want = self._next_to_consume
        t0 = time.perf_counter()
        while True:
            with self._stash_lock:
                if want in self._stash:
                    batch = self._stash.pop(want)
                    break
            step, batch = self._q.get()
            if step == want:
                break
            with self._stash_lock:
                self._stash[step] = batch
        self._wait_s += time.perf_counter() - t0
        self._batches += 1
        self._next_to_consume += 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.get()

    # -- accounting ----------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        return {
            "batches": float(self._batches),
            "input_wait_s": self._wait_s,
            "input_wait_per_batch_ms": (
                1e3 * self._wait_s / self._batches if self._batches else 0.0
            ),
            "workers": float(self.workers),
            "max_queue_size": float(self.max_queue_size),
        }

    @staticmethod
    def queue_bytes(batch: Dict[str, np.ndarray], max_queue_size: int) -> int:
        """RAM bound of the prefetch queue (paper's F7 memory accounting)."""
        per = sum(a.nbytes for a in batch.values())
        return per * max_queue_size
