"""AdamW with decoupled weight decay, global-norm clipping, and
param-sharded (ZeRO) optimizer state.

State is a pytree mirroring params: m and v in f32, sharded with the *same*
PartitionSpecs as their parameters so the optimizer never gathers anything —
the update is purely elementwise and runs fully sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array  # scalar int32
    m: Params  # f32, like params
    v: Params  # f32, like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # keep master params in f32? (params may themselves be bf16)
    mu_dtype: Any = jnp.float32


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to lr_min; pure jnp so it jits."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    decay_steps = max(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params: Params, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.mu_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def _decay_mask(path) -> bool:
    """Decay matmul kernels / embeddings; skip norms, biases, gains."""
    names = [str(e.key) for e in path if isinstance(e, jax.tree_util.DictKey)]
    leaf = names[-1] if names else ""
    no_decay = ("scale", "bias", "mu", "decay_base", "bonus_u", "b", "bq", "bk",
                "bv", "bo", "b_up", "b_down", "dt_bias", "a_log", "d_skip")
    return leaf not in no_decay


def apply_updates(
    params: Params,
    grads: Params,
    state: AdamWState,
    cfg: AdamWConfig,
) -> Tuple[Params, AdamWState, Dict[str, jax.Array]]:
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree_util.tree_map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.m, grads
    )
    new_v = jax.tree_util.tree_map(
        lambda v, g: cfg.b2 * v + (1 - cfg.b2) * jnp.square(g), state.v, grads
    )

    def upd(path, p, m, v):
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map_with_path(upd, params, new_m, new_v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
