"""Error-feedback int8 gradient compression for the DCN (`pod`) axis.

Cross-pod links are an order of magnitude slower than intra-pod ICI, so the
pod-axis gradient all-reduce is the one collective worth compressing. The
scheme is standard EF-SGD quantization:

    q = round(clip((g + e) / s, -127, 127));  psum(q);  g' = s * q / n_pods
    e' = (g + e) - s * q          (local error feedback, carried in state)

with one f32 scale per tensor, all-reduced with MAX so every pod uses the
same scale. Designed for use *inside* ``jax.shard_map`` over the ``pod``
axis; intra-pod axes stay automatic so GSPMD still shards the model.

Wire cost: 1 byte/grad element + 4 bytes/tensor, i.e. 4x less DCN traffic
than f32 and 2x less than bf16 all-reduce.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def init_error_state(grads_like: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
    )


def _quantize(x: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8)


def ef_int8_psum(
    grads: Params,
    err: Params,
    axis_name: str,
) -> Tuple[Params, Params]:
    """Compressed mean over ``axis_name``; returns (mean_grads, new_err).

    Must run inside shard_map with ``axis_name`` manual.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        scale = jax.lax.pmax(scale, axis_name)  # shared scale across pods
        q = _quantize(gf, scale)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = (summed.astype(jnp.float32) * scale) / n
        new_e = gf - q.astype(jnp.float32) * scale  # local residual
        return mean.astype(g.dtype), new_e

    flat, treedef = jax.tree_util.tree_flatten(grads)
    eflat = jax.tree_util.tree_leaves(err)
    out = [one(g, e) for g, e in zip(flat, eflat)]
    means = jax.tree_util.tree_unflatten(treedef, [m for m, _ in out])
    errs = jax.tree_util.tree_unflatten(treedef, [e for _, e in out])
    return means, errs


def uncompressed_psum(grads: Params, axis_name: str) -> Params:
    n = jax.lax.psum(1, axis_name)
    return jax.tree_util.tree_map(
        lambda g: jax.lax.psum(g.astype(jnp.float32), axis_name) / n, grads
    )


def compression_wire_bytes(grads_like: Params) -> Tuple[int, int]:
    """(f32 all-reduce bytes, ef-int8 bytes) per pod-axis reduction."""
    leaves = jax.tree_util.tree_leaves(grads_like)
    n_elems = sum(int(jnp.size(jnp.zeros(l.shape, jnp.int8))) for l in leaves)
    full = 4 * n_elems
    compressed = n_elems + 4 * len(leaves)
    return full, compressed
