"""rwkv6-1.6b [ssm/linear-attn] — Finch, data-dependent decay
(arXiv:2404.05892). Attention-free: runs long_500k."""
from repro.configs.base import ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv",
    n_layers=24,
    d_model=2048,
    n_heads=32,      # wkv heads = d_model / head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    norm="layernorm",
    ssm=SSMSpec(kind="rwkv6", state_dim=64, head_dim=64, chunk=64, lora_rank=64),
)
