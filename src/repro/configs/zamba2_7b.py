"""zamba2-7b [hybrid] — Mamba2 backbone + one shared attention block applied
periodically (arXiv:2411.15242). Sub-quadratic: runs long_500k."""
from repro.configs.base import ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    attn_every=27,  # 81 mamba blocks in 3 groups, shared attn before each group
    ssm=SSMSpec(kind="mamba2", state_dim=64, head_dim=64, d_conv=4, expand=2, chunk=64),
    rope_theta=10_000.0,
)
