"""The paper's own workload trio (ResNet-V2 on image data, batch 32)."""
from repro.configs.base import ModelConfig

def _resnet(name, stages, img, classes):
    return ModelConfig(
        name=name, family="resnet", n_layers=sum(stages) * 3 + 2,
        d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab=0,
        stages=stages, img_size=img, n_classes=classes, remat=False,
    )

RESNET_SMALL = _resnet("resnet_small", (2, 2, 2, 2), 32, 10)      # ResNet26-V2 / CIFAR-10
RESNET_MEDIUM = _resnet("resnet_medium", (3, 4, 6, 3), 64, 1000)  # ResNet50-V2 / ImageNet64
RESNET_LARGE = _resnet("resnet_large", (3, 8, 36, 3), 224, 1000)  # ResNet152-V2 / ImageNet
