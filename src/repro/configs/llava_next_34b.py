"""llava-next-34b [vlm] — anyres tiling frontend is a STUB: input_specs
provides precomputed patch embeddings (B, n_patches, d_model); the 60L GQA
backbone is real (hf:llava-hf/llava-v1.6 family)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    n_patches=576,  # one 24x24 anyres tile worth of patch embeddings
    rope_theta=5_000_000.0,
)
