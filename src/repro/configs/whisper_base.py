"""whisper-base [audio enc-dec] — conv frontend is a STUB: input_specs
provides precomputed frame embeddings (B, 1500, d); the 6L+6L backbone is
real (arXiv:2212.04356)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    norm="layernorm",
    act="gelu",
    n_frames=1500,
    tie_embeddings=True,
    max_dec_pos=32_768,  # shape-faithful to decode_32k (real model caps at 448)
)
