"""deepseek-moe-16b [moe] — fine-grained: 2 shared + 64 routed top-6
(arXiv:2401.06066)."""
from repro.configs.base import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    moe=MoESpec(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    rope_theta=10_000.0,
)
