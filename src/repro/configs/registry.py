"""Architecture registry: ``--arch <id>`` resolution for every entry point."""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.base import (
    ALL_SHAPES,
    SHAPES_BY_NAME,
    ModelConfig,
    ShapeSuite,
    shape_applicable,
)
from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.granite_3_2b import CONFIG as _granite
from repro.configs.llama3_8b import CONFIG as _llama3
from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.qwen2_72b import CONFIG as _qwen2
from repro.configs.resnet_trio import RESNET_LARGE, RESNET_MEDIUM, RESNET_SMALL
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6
from repro.configs.stablelm_12b import CONFIG as _stablelm
from repro.configs.whisper_base import CONFIG as _whisper
from repro.configs.zamba2_7b import CONFIG as _zamba2

# the 10 assigned architectures
ASSIGNED: Dict[str, ModelConfig] = {
    "stablelm-12b": _stablelm,
    "qwen2-72b": _qwen2,
    "granite-3-2b": _granite,
    "llama3-8b": _llama3,
    "llava-next-34b": _llava,
    "rwkv6-1.6b": _rwkv6,
    "deepseek-moe-16b": _deepseek,
    "olmoe-1b-7b": _olmoe,
    "whisper-base": _whisper,
    "zamba2-7b": _zamba2,
}

# the paper's own workload trio (collocation study)
PAPER_WORKLOADS: Dict[str, ModelConfig] = {
    "resnet_small": RESNET_SMALL,
    "resnet_medium": RESNET_MEDIUM,
    "resnet_large": RESNET_LARGE,
}

CONFIGS: Dict[str, ModelConfig] = {**ASSIGNED, **PAPER_WORKLOADS}


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(CONFIGS)}")
    return CONFIGS[name]


def dryrun_grid() -> List[Tuple[str, str, bool, str]]:
    """The full 40-cell grid: (arch, shape, applicable, skip_reason)."""
    cells = []
    for arch, cfg in ASSIGNED.items():
        for suite in ALL_SHAPES:
            ok, why = shape_applicable(cfg, suite)
            cells.append((arch, suite.name, ok, why))
    return cells
