"""Unified model configuration across all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts FFN spec (deepseek-moe / olmoe style)."""

    n_experts: int
    top_k: int
    d_expert: int  # hidden width of each routed expert
    n_shared: int = 0  # fused shared-expert count (deepseek fine-grained)
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    # expert-capacity factor; reduced configs set it high so no token is
    # ever dropped and decode == teacher-forced forward exactly
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    """State-space / linear-attention spec (rwkv6, mamba2)."""

    kind: str  # 'rwkv6' | 'mamba2'
    state_dim: int = 64  # N (mamba2) or head_dim (rwkv6 K)
    head_dim: int = 64
    d_conv: int = 4  # mamba2 short conv
    expand: int = 2  # mamba2 inner expansion
    chunk: int = 64  # chunked-scan block length
    lora_rank: int = 64  # rwkv6 data-dependent decay LoRA rank


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config drives every family; unused fields stay at their defaults."""

    name: str
    family: str  # dense|moe|rwkv|hybrid|encdec|vlm|resnet
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    # hybrid (zamba2): a single *shared* attention block applied before every
    # ``attn_every``-th ssm layer.
    attn_every: int = 0
    # enc-dec (whisper)
    enc_layers: int = 0
    n_frames: int = 1500  # stub audio frontend: precomputed frame embeddings
    # vlm (llava): stub patch embeddings prepended to the token stream
    n_patches: int = 0
    # enc-dec decoder positional table size (whisper)
    max_dec_pos: int = 32_768
    # resnet (paper workload trio)
    img_size: int = 0
    n_classes: int = 0
    stages: Tuple[int, ...] = ()
    base_width: int = 64
    # numerics / runtime
    remat: bool = True
    attn_block_q: int = 512  # xla-flash blocking
    attn_block_k: int = 1024
    logit_softcap: float = 0.0
    label_smoothing: float = 0.0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a TP-shardable multiple (Megatron-style).

        Logical vocab is unchanged; pad logits are masked to -inf in
        ``logits_fn`` and synthetic data never emits pad ids.
        """
        mult = 16
        return -(-self.vocab // mult) * mult

    @property
    def q_groups(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=min(self.d_model, 64),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 128),
            vocab=min(self.vocab, 256),
            head_dim=16 if self.resolved_head_dim > 16 else self.resolved_head_dim,
            enc_layers=min(self.enc_layers, 2),
            n_frames=min(self.n_frames, 8) if self.enc_layers else self.n_frames,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            attn_every=2 if self.attn_every else 0,
            attn_block_q=8,
            attn_block_k=8,
            remat=False,
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=min(self.moe.top_k, 2), d_expert=32,
                n_shared=min(self.moe.n_shared, 1), capacity_factor=8.0,
            )
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(
                self.ssm, state_dim=8, head_dim=8, chunk=8, lora_rank=8,
            )
        if self.family == "resnet":
            small.update(
                img_size=min(self.img_size, 32),
                n_classes=min(self.n_classes, 10),
                stages=tuple(min(s, 2) for s in self.stages),
                base_width=8,
            )
        if self.enc_layers:
            small["max_dec_pos"] = 128
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# shape suites (assigned input shapes)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSuite:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeSuite("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSuite("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSuite("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSuite("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSuite, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}

# Families with sub-quadratic sequence mixing may run long_500k.
SUBQUADRATIC_FAMILIES = ("rwkv", "hybrid")


def shape_applicable(cfg: ModelConfig, suite: ShapeSuite) -> Tuple[bool, str]:
    """(applicable?, reason-if-not). Encodes the DESIGN.md §4 skip table."""
    if suite.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "full-attention arch: O(S^2) at 500k — skipped per DESIGN.md"
    if suite.kind == "decode" and cfg.family == "resnet":
        return False, "CNN classifier has no autoregressive decode"
    return True, ""
