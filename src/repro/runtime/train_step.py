"""Train/eval step builders: grad-accum, donation, and GSPMD sharding glue.

``build_train_step`` returns a pure function over a ``TrainState`` dict pytree
{"params", "opt"}; ``jit_train_step`` wraps it in ``jax.jit`` with in/out
shardings derived from the rule-based parameter PartitionSpecs and the
activation plan, donating the state so params/optimizer are updated in place.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSuite
from repro.models.model_api import Model
from repro.optim import adamw
from repro.sharding.plan import (
    ShardingPlan,
    make_plan,
    param_pspecs,
    validate_pspecs,
    zero_param_pspecs,
)

TrainState = Dict[str, Any]  # {"params": pytree, "opt": AdamWState}


def init_train_state(model: Model, key: jax.Array, opt_cfg: adamw.AdamWConfig):
    params = model.init(key)
    return {"params": params, "opt": adamw.init_state(params, opt_cfg)}


def build_train_step(
    model: Model,
    plan: ShardingPlan,
    opt_cfg: adamw.AdamWConfig,
    *,
    grad_accum: int = 1,
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict]]:
    """Pure (state, batch) -> (state, metrics), with optional microbatching.

    grad_accum > 1 splits the global batch into ``grad_accum`` microbatches
    along dim 0 and accumulates grads in f32 under ``lax.scan`` — peak
    activation memory drops by ~grad_accum at the cost of re-running the
    (already rematerialized) forward.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch, plan)

    def single(state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch
        )
        return loss, metrics, grads

    def accumulated(state, batch):
        def reshape(x):
            return x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:])

        micro = jax.tree_util.tree_map(reshape, batch)
        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
        )

        def body(acc, mb):
            g_acc, loss_acc = acc
            (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], mb
            )
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g
            )
            return (g_acc, loss_acc + loss), metrics

        (grads, loss_sum), metrics = jax.lax.scan(body, (g0, jnp.float32(0)), micro)
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss_sum / grad_accum, metrics, grads

    def train_step(state: TrainState, batch):
        loss, metrics, grads = (
            single(state, batch) if grad_accum == 1 else accumulated(state, batch)
        )
        new_params, new_opt, opt_metrics = adamw.apply_updates(
            state["params"], grads, state["opt"], opt_cfg
        )
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


# ---------------------------------------------------------------------------
# sharding glue
# ---------------------------------------------------------------------------


def state_shardings(model: Model, mesh: Mesh, variant: str = "baseline"):
    """NamedSharding pytree for the TrainState, from the rule-based pspecs."""
    params_shape = jax.eval_shape(model.init, jax.random.key(0))
    if variant == "zero":
        specs = zero_param_pspecs(params_shape, mesh)
    else:
        specs = validate_pspecs(params_shape, param_pspecs(params_shape), mesh)
    p_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)
    scalar = NamedSharding(mesh, P())
    return {
        "params": p_sh,
        "opt": adamw.AdamWState(step=scalar, m=p_sh, v=p_sh),
    }


def batch_shardings(model: Model, mesh: Mesh, suite: ShapeSuite, plan: ShardingPlan):
    specs = model.input_specs(suite)
    batch_axes = plan.spec("tokens")[0] if len(plan.spec("tokens")) else None
    out = {}
    for k, v in specs.items():
        # batch dim over the data axes (when divisible — plan.spec('tokens')
        # already encodes the fallback), remaining dims unsharded.
        spec = P(batch_axes, *((None,) * (v.ndim - 1)))
        if k in ("patches", "frames"):
            spec = plan.spec("frames")
        out[k] = NamedSharding(mesh, spec)
    return out


def jit_train_step(
    model: Model,
    mesh: Mesh,
    suite: ShapeSuite,
    opt_cfg: adamw.AdamWConfig,
    *,
    grad_accum: int = 1,
    donate: bool = True,
    variant: str = "baseline",
):
    """jit'd train step + (state_shardings, batch_shardings) for callers."""
    plan = make_plan(model.cfg, mesh, suite, variant=variant)
    step_fn = build_train_step(model, plan, opt_cfg, grad_accum=grad_accum)
    st_sh = state_shardings(model, mesh, variant)
    b_sh = batch_shardings(model, mesh, suite, plan)
    jitted = jax.jit(
        step_fn,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, None),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, st_sh, b_sh, plan
