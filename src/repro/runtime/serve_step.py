"""Serving-step builders: batched prefill and single-token decode with a
sharded KV cache. ``decode`` is the step lowered for decode_32k / long_500k
dry-run cells (one new token against a seq_len-long cache), per the brief.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeSuite
from repro.models.model_api import Model
from repro.sharding.plan import (
    ShardingPlan,
    make_plan,
    param_pspecs,
    serve_param_pspecs,
    validate_pspecs,
    zero_param_pspecs,
)


def param_shardings(model: Model, mesh: Mesh, variant: str = "baseline"):
    shape = jax.eval_shape(model.init, jax.random.key(0))
    if variant == "zero":
        specs = zero_param_pspecs(shape, mesh)
    elif variant == "serve":
        specs = serve_param_pspecs(shape, mesh)
    else:
        specs = validate_pspecs(shape, param_pspecs(shape), mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def _fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop any axis assignment that does not divide the dim (divisibility
    safety net — batch-1 long-context cells, 1500-frame cross-KV, etc.)."""
    fixed = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(entry if size and shape[i] % size == 0 else None)
    fixed += [None] * (len(shape) - len(fixed))
    return P(*fixed[: len(shape)])


def cache_shardings(model: Model, mesh: Mesh, suite: ShapeSuite, plan: ShardingPlan):
    spec_tree = model.cache_spec(suite.global_batch, suite.seq_len)

    def rule(path, leaf):
        name = str(path[-1].key) if path else ""
        if name in ("k", "v", "xk", "xv"):
            spec = plan.spec("cache")
        elif name in ("wkv", "ssm"):
            spec = plan.spec("state")
        else:
            # token-shift tails / conv tails: small, batch-sharded
            dp = plan.dp_axes if plan.dp_axes else None
            spec = P(None, dp, *((None,) * (leaf.ndim - 2)))
        return NamedSharding(mesh, _fit_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(rule, spec_tree)


def build_prefill(model: Model, plan: ShardingPlan):
    def prefill_step(params, batch):
        return model.prefill(params, batch, plan)

    return prefill_step


def build_decode(model: Model, plan: ShardingPlan, pos: int):
    """One-token decode step at static cache position ``pos``."""

    def decode_step(params, batch, cache):
        return model.decode(params, batch, cache, pos, plan)

    return decode_step


def jit_decode_step(model: Model, mesh: Mesh, suite: ShapeSuite,
                    variant: str = "baseline"):
    """jit'd decode step with cache donation (in-place KV update)."""
    plan = make_plan(model.cfg, mesh, suite, variant=variant)
    p_sh = param_shardings(model, mesh, variant)
    c_sh = cache_shardings(model, mesh, suite, plan)
    # token batch sharding must respect divisibility (batch=1 long-context
    # cells leave the batch dim unsharded — plan.spec('tokens') encodes that)
    tok_batch_axis = plan.spec("tokens")[0] if len(plan.spec("tokens")) else None
    tok_sh = {"token": NamedSharding(mesh, P(tok_batch_axis))}
    if model.cfg.enc_layers:
        tok_sh["frames"] = NamedSharding(mesh, plan.spec("frames"))
    step = build_decode(model, plan, suite.seq_len - 1)
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, tok_sh, c_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )
    return jitted, p_sh, tok_sh, c_sh, plan


def jit_prefill_step(model: Model, mesh: Mesh, suite: ShapeSuite,
                     variant: str = "baseline"):
    plan = make_plan(model.cfg, mesh, suite, variant=variant)
    p_sh = param_shardings(model, mesh, variant)
    b_sh = {"tokens": NamedSharding(mesh, plan.spec("tokens"))}
    if model.cfg.n_patches:
        b_sh["patches"] = NamedSharding(mesh, plan.spec("frames"))
    if model.cfg.enc_layers:
        b_sh["frames"] = NamedSharding(mesh, plan.spec("frames"))
    c_sh = cache_shardings(model, mesh, suite, plan)
    jitted = jax.jit(
        build_prefill(model, plan),
        in_shardings=(p_sh, b_sh),
        out_shardings=(None, c_sh),
    )
    return jitted, p_sh, b_sh, plan


def pad_cache(cache, extra: int):
    """Grow the self-attention KV seq dim by ``extra`` slots after prefill."""

    def pad(path, leaf):
        name = str(path[-1].key) if path else ""
        if name in ("k", "v", "attn_k", "attn_v") and leaf.ndim == 5:
            cfgpad = [(0, 0)] * leaf.ndim
            cfgpad[2] = (0, extra)
            return jnp.pad(leaf, cfgpad)
        return leaf

    return jax.tree_util.tree_map_with_path(pad, cache)


def greedy_generate(
    model: Model,
    params,
    prompt: jax.Array,  # (B, S) int32
    max_new: int,
    plan: ShardingPlan,
):
    """Eager greedy decoding loop for examples/tests (CPU-scale)."""
    B, S = prompt.shape
    last, cache = model.prefill(params, {"tokens": prompt}, plan)
    cache = pad_cache(cache, max_new)
    tokens = [jnp.argmax(last, axis=-1).astype(jnp.int32)]
    for i in range(max_new - 1):
        logits, cache = model.decode(
            params, {"token": tokens[-1]}, cache, S + i, plan
        )
        tokens.append(jnp.argmax(logits, axis=-1).astype(jnp.int32))
    return jnp.stack(tokens, axis=1)  # (B, max_new)
