"""JAX version compatibility shims.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace around jax 0.5, and its replication-check parameter was
renamed ``check_rep`` -> ``check_vma`` later still — so there is a version
window where ``jax.shard_map`` exists but only accepts ``check_rep``. This
wrapper accepts the new spelling and dispatches on the parameter the
installed implementation actually takes.
"""
from __future__ import annotations

import inspect

import jax


def _impl():
    if hasattr(jax, "shard_map"):
        return jax.shard_map
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map


_SHARD_MAP = _impl()
_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_SHARD_MAP).parameters
    else "check_rep"
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    return _SHARD_MAP(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: check_vma},
    )
