"""Ring collective-matmul: compute/communication overlap primitive.

``ring_ag_matmul`` computes ``y = x @ W`` where ``x`` is batch-sharded and
``W`` is column-sharded over the same axis, *without* a blocking all-gather
of W: at ring step k each device multiplies against the weight shard it
currently holds while ``ppermute`` forwards that shard to its neighbour.
XLA overlaps the (independent) matmul and permute, hiding ICI latency behind
MXU work — the standard TPU collective-matmul pattern, used by the hillclimb
as a beyond-paper optimization and validated against the all-gather oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ring_ag_matmul(x: jax.Array, w_shard: jax.Array, axis_name: str) -> jax.Array:
    """x: (B_local, d); w_shard: (d, f_local) — this device's column block.

    Returns (B_local, N * f_local): this device's batch rows against the
    full weight, accumulated one column block per ring step. Must run inside
    shard_map with ``axis_name`` manual.
    """
    n = jax.lax.psum(1, axis_name)
    me = jax.lax.axis_index(axis_name)
    f_local = w_shard.shape[1]
    perm = [(i, (i - 1) % n) for i in range(n)]  # shift shards "down" the ring

    def body(carry, k):
        w, out = carry
        # shard currently held came from device (me + k) % n -> column block
        blk = (me + k) % n
        part = jnp.einsum("bd,df->bf", x, w, preferred_element_type=jnp.float32)
        out = jax.lax.dynamic_update_slice(
            out, part.astype(out.dtype), (0, blk * f_local)
        )
        w = jax.lax.ppermute(w, axis_name, perm)
        return (w, out), None

    out0 = jnp.zeros((x.shape[0], n * f_local), jnp.float32)
    (_, out), _ = jax.lax.scan(body, (w_shard, out0), jnp.arange(n))
    return out


def ring_rs_matmul(x: jax.Array, w_shard: jax.Array, axis_name: str) -> jax.Array:
    """Reduce-scatter flavour: x: (B_local, N*f_local) activation sharded on
    batch, w_shard: (f_local, d) — this device's *row* block of a
    (N*f_local, d) matrix. Computes ``(x @ W)`` reduce-scattered over batch
    is not needed here; instead we return each device's partial-sum chain:
    y = sum_k x[:, blk_k] @ W_k, accumulated around the ring so each step's
    psum chunk overlaps the next matmul. Output: (B_local, d) full sum.
    """
    n = jax.lax.psum(1, axis_name)
    me = jax.lax.axis_index(axis_name)
    f_local = w_shard.shape[0]
    perm = [(i, (i - 1) % n) for i in range(n)]

    def body(carry, k):
        w, acc = carry
        blk = (me + k) % n
        xk = jax.lax.dynamic_slice(x, (0, blk * f_local), (x.shape[0], f_local))
        acc = acc + jnp.einsum(
            "bf,fd->bd", xk, w, preferred_element_type=jnp.float32
        )
        w = jax.lax.ppermute(w, axis_name, perm)
        return (w, acc), None

    acc0 = jnp.zeros((x.shape[0], w_shard.shape[1]), jnp.float32)
    (_, acc), _ = jax.lax.scan(body, (w_shard, acc0), jnp.arange(n))
    return acc
