"""GPipe-style pipeline parallelism over ``shard_map`` + ``ppermute``.

The paper's technique partitions *between* jobs, so PP is not the default
axis mapping — but a 1000+-node posture needs it available. This module
implements a self-contained microbatch pipeline for the stacked-layer dense
transformer: stage s owns layers [s*L/S, (s+1)*L/S); activations flow stage
to stage with ``collective_permute``; the classic GPipe schedule runs
(num_micro + num_stages - 1) ticks with bubble fraction (S-1)/(M+S-1).

Used by tests (8 host devices) and by the hillclimb as an alternative
mapping; correctness oracle = the plain scanned forward.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import module as nn
from repro.models import transformer as tfm
from repro.runtime.compat import shard_map
from repro.sharding.plan import ShardingPlan


def pipeline_forward(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,  # (M, mb, S) microbatched token ids
    mesh: Mesh,
    *,
    stage_axis: str = "stage",
):
    """Pipelined forward producing logits (M, mb, S, V).

    ``params['layers']`` leaves have leading dim L = n_layers; the stage axis
    must divide L. Embedding/head run on every stage (cheap, replicated math)
    with masking selecting the true first/last stage contributions.
    """
    n_stages = mesh.shape[stage_axis]
    L = cfg.n_layers
    assert L % n_stages == 0, (L, n_stages)
    per_stage = L // n_stages
    M = tokens.shape[0]
    plan = ShardingPlan(None, {}, (), None)  # inside shard_map: no constraints

    def stage_fn(layers_stacked, embed, final_norm, lm_head, toks):
        """Runs on one device = one stage. toks: (M, mb, S)."""
        sid = jax.lax.axis_index(stage_axis)
        mb, S = toks.shape[1], toks.shape[2]
        d = cfg.d_model

        h_in = nn.embedding_apply(embed, toks)  # (M, mb, S, d) — used by stage 0

        def tick(carry, t):
            buf = carry  # (mb, S, d) activation arriving this tick
            # microbatch index this stage works on at tick t
            m_idx = t - sid
            active = (m_idx >= 0) & (m_idx < M)
            x = jnp.where(
                sid == 0,
                h_in[jnp.clip(m_idx, 0, M - 1)].astype(jnp.float32),
                buf.astype(jnp.float32),
            ).astype(jnp.bfloat16)

            body = functools.partial(tfm.block_fwd, cfg, plan)
            y = nn.scan_layers(body, x, layers_stacked)
            y = jnp.where(active, y.astype(jnp.float32), 0.0)

            # pass activation to the next stage (ring; last stage's output
            # wraps to stage 0 where it is ignored)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(y, stage_axis, perm)
            # last stage emits logits for microbatch m_idx
            out = jnp.where(
                active & (sid == n_stages - 1),
                y.astype(jnp.float32),
                0.0,
            )
            return nxt.astype(jnp.bfloat16), (out, m_idx, active & (sid == n_stages - 1))

        ticks = M + n_stages - 1
        buf0 = jnp.zeros((mb, S, d), jnp.bfloat16)
        _, (outs, m_idxs, valid) = jax.lax.scan(
            tick, buf0, jnp.arange(ticks)
        )
        # scatter tick outputs back to microbatch order
        h_out = jnp.zeros((M, mb, S, d), jnp.float32)
        h_out = h_out.at[jnp.clip(m_idxs, 0, M - 1)].add(
            outs * valid[:, None, None, None]
        )
        h_out = h_out.astype(jnp.bfloat16)
        logits = tfm.logits_fn(cfg, {**lm_head, "final_norm": final_norm}, h_out, plan)
        # only the last stage holds real logits; share them with everyone
        logits = jax.lax.psum(
            jnp.where(sid == n_stages - 1, logits.astype(jnp.float32), 0.0),
            stage_axis,
        )
        return logits

    # split stacked layers across stages; replicate everything else
    lspec = jax.tree_util.tree_map(
        lambda a: P(*((stage_axis,) + (None,) * (a.ndim - 1))), params["layers"]
    )
    rep = lambda tree: jax.tree_util.tree_map(lambda a: P(), tree)
    head = {k: params[k] for k in ("lm_head",) if k in params}
    if cfg.tie_embeddings:
        head = {"embed": params["embed"]}

    fn = shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(
            lspec,
            rep(params["embed"]),
            rep(params["final_norm"]),
            rep(head),
            P(),
        ),
        out_specs=P(),
        # the tick scan mixes stage-varying (buf) and replicated (h_in)
        # carries; vma checking would demand explicit pvary casts that XLA
        # elides anyway (and whose copy-combiner all-reduces crash XLA:CPU —
        # see models/moe.py)
        check_vma=False,
    )
    return fn(params["layers"], params["embed"], params["final_norm"], head, tokens)
