"""Sharded checkpoint store: atomic manifests, async saves, resume.

Fault-tolerance contract (the substrate elastic repack and multi-thousand-
node posture rely on):

  * a checkpoint is VALID iff its ``manifest.json`` exists — the manifest is
    written LAST and renamed into place atomically, so a writer killed
    mid-save never leaves a readable-but-corrupt step;
  * array leaves are saved per-shard: each host writes only the shards it
    owns (``leaf.addressable_shards``), so save bandwidth scales with hosts
    and no host needs global-array RAM (on this single-host container that
    degenerates to one shard per leaf — the layout is identical);
  * saves can run on a background thread (``async_save=True``): the train
    loop donates nothing, since leaves are device->host copied before the
    thread starts, and the previous async save is joined before a new one
    begins (bounded memory);
  * ``restore`` reassembles leaves and (optionally) re-shards them onto a
    *different* mesh — the elastic-repack path: a job killed on a 2g
    instance resumes on a 3g instance from the same files;
  * integrity: every shard file carries a crc32 in the manifest, checked on
    restore.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

MANIFEST = "manifest.json"


def _path_entry(p) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(_path_entry(p) for p in path), leaf) for path, leaf in flat]


def _tree_def(tree):
    return jax.tree_util.tree_structure(tree)


@dataclasses.dataclass
class CheckpointInfo:
    step: int
    path: Path
    wall_time: float


class CheckpointStore:
    """Directory layout: <root>/step_<n>/{leaf files, manifest.json}."""

    def __init__(self, root: str | Path, *, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async_thread: Optional[threading.Thread] = None

    # -- save -------------------------------------------------------------------

    def save(self, step: int, tree, *, extra: Optional[Dict] = None,
             async_save: bool = False) -> Path:
        """Save ``tree`` (pytree of jax/np arrays) at ``step``."""
        self.wait()  # join any in-flight async save (bounded memory)
        # device->host copy NOW so the caller may donate/mutate afterwards
        host_leaves = []
        for key, leaf in _leaf_paths(tree):
            if isinstance(leaf, jax.Array):
                shards = [
                    (i, np.asarray(s.data)) for i, s in enumerate(leaf.addressable_shards)
                ]
            else:
                # snapshot semantics: np leaves must be COPIED, or an async
                # writer would observe later caller mutations
                shards = [(0, np.array(leaf, copy=True))]
            host_leaves.append((key, leaf, shards))

        if async_save:
            t = threading.Thread(
                target=self._write, args=(step, tree, host_leaves, extra), daemon=True
            )
            t.start()
            self._async_thread = t
            return self.root / f"step_{step:08d}"
        return self._write(step, tree, host_leaves, extra)

    def _write(self, step, tree, host_leaves, extra) -> Path:
        final = self.root / f"step_{step:08d}"
        tmp = self.root / f".tmp_step_{step:08d}"
        if tmp.exists():
            for f in tmp.iterdir():
                f.unlink()
        tmp.mkdir(parents=True, exist_ok=True)

        leaves_meta = []
        for key, leaf, shards in host_leaves:
            fname = key.replace("/", "__") + ".npy"
            shard_meta = []
            for idx, arr in shards:
                sf = f"{fname}.shard{idx}" if len(shards) > 1 else fname
                with open(tmp / sf, "wb") as f:
                    np.save(f, arr)
                shard_meta.append(
                    {"file": sf, "index": idx, "crc32": zlib.crc32(arr.tobytes())}
                )
            leaves_meta.append(
                {
                    "key": key,
                    "shape": list(np.shape(leaf)),
                    "dtype": str(np.asarray(shards[0][1]).dtype),
                    "shards": shard_meta,
                }
            )
        manifest = {
            "step": step,
            "wall_time": time.time(),
            "leaves": leaves_meta,
            "extra": extra or {},
        }
        # manifest LAST + atomic rename = crash consistency
        mtmp = tmp / (MANIFEST + ".tmp")
        mtmp.write_text(json.dumps(manifest, indent=1))
        mtmp.rename(tmp / MANIFEST)
        if final.exists():
            import shutil

            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _gc(self):
        ckpts = self.list()
        for info in ckpts[: -self.keep]:
            import shutil

            shutil.rmtree(info.path, ignore_errors=True)

    # -- restore -----------------------------------------------------------------

    def list(self) -> List[CheckpointInfo]:
        out = []
        for d in sorted(self.root.glob("step_*")):
            man = d / MANIFEST
            if not man.exists():
                continue  # incomplete save — invisible by contract
            meta = json.loads(man.read_text())
            out.append(CheckpointInfo(meta["step"], d, meta["wall_time"]))
        return out

    def latest_step(self) -> Optional[int]:
        ckpts = self.list()
        return ckpts[-1].step if ckpts else None

    def restore(
        self, tree_like, step: Optional[int] = None, *, shardings=None
    ) -> Tuple[Any, Dict]:
        """Restore into the structure of ``tree_like``.

        ``shardings``: optional pytree of NamedSharding to place leaves onto
        (may describe a different mesh than the one that saved — elastic
        resume). Returns (tree, extra).
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:08d}"
        meta = json.loads((d / MANIFEST).read_text())
        by_key = {m["key"]: m for m in meta["leaves"]}

        keys = [k for k, _ in _leaf_paths(tree_like)]
        sh_leaves = (
            [s for _, s in _leaf_paths(shardings)] if shardings is not None else [None] * len(keys)
        )
        leaves = []
        for key, sh in zip(keys, sh_leaves):
            m = by_key[key]
            parts = []
            for smeta in sorted(m["shards"], key=lambda s: s["index"]):
                with open(d / smeta["file"], "rb") as f:
                    arr = np.load(f)
                if zlib.crc32(arr.tobytes()) != smeta["crc32"]:
                    raise IOError(f"crc mismatch in {d / smeta['file']}")
                if arr.dtype.kind == "V":
                    # ml_dtypes (bfloat16 etc.) round-trip through np.save as
                    # raw void bytes — reinterpret via the manifest dtype.
                    import ml_dtypes

                    arr = arr.view(np.dtype(getattr(ml_dtypes, m["dtype"])))
                parts.append(arr)
            if len(parts) == 1:
                full = parts[0]
            else:
                # single-host reassembly: shards were equal splits on axis 0
                full = np.concatenate(parts, axis=0)
            if list(full.shape) != m["shape"]:
                full = full.reshape(m["shape"])
            if sh is not None:
                leaves.append(jax.device_put(full, sh))
            else:
                leaves.append(jnp.asarray(full))
        tree = jax.tree_util.tree_unflatten(_tree_def(tree_like), leaves)
        return tree, meta.get("extra", {})
